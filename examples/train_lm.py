"""End-to-end training driver: LM + ZoloMuon (the paper's PD inside every
step), with checkpoint/restart and metrics.

Default: a ~15M-param mamba2-family model for 200 steps (CPU-sized).
``--arch``/``--steps``/``--full`` scale it up; the full configs are
exercised at production scale via launch/dryrun.py.

  PYTHONPATH=src python examples/train_lm.py
  PYTHONPATH=src python examples/train_lm.py --arch qwen3-8b --steps 50
"""

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro import configs as CFG  # noqa: E402
from repro.checkpoint.manager import CheckpointManager  # noqa: E402
from repro.data.pipeline import SyntheticLM  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.optim.muon import MuonConfig  # noqa: E402
from repro.train.loop import TrainLoop  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402


def cpu_sized_config(arch: str):
    """~15M params: big enough to exercise every code path, small enough
    for a few hundred CPU steps."""
    cfg = CFG.get_config(arch)
    return dataclasses.replace(
        cfg, num_layers=max(len(cfg.block_pattern) * 2,
                            4 - (4 % len(cfg.block_pattern))),
        d_model=256,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads else 0,
        head_dim=64 if cfg.num_heads else 0,
        d_ff=min(cfg.d_ff, 1024) if cfg.d_ff else 0,
        rnn_width=256 if cfg.rnn_width else 0,
        vocab_size=min(cfg.vocab_size, 8192),
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        window=min(cfg.window, 256) if cfg.window else None,
        num_prefix_embeds=min(cfg.num_prefix_embeds, 16),
        dtype="float32",
    ).validate()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--method", default="zolo",
                    choices=["zolo", "qdwh", "ns5"])
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (not CPU-sized)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = CFG.get_config(args.arch) if args.full \
        else cpu_sized_config(args.arch)
    init_fn, step_fn = make_train_step(
        cfg, MuonConfig(lr=0.02, method=args.method),
        total_steps=args.steps)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                       num_prefix_embeds=cfg.num_prefix_embeds,
                       d_model=cfg.d_model, dtype=cfg.dtype)
    ckpt = CheckpointManager(args.ckpt_dir, keep_k=2)
    loop = TrainLoop(step_fn, data, ckpt=ckpt, ckpt_every=50, log_every=10,
                     tokens_per_step=args.batch * args.seq)
    state = loop.resume_or_init(init_fn, jax.random.PRNGKey(0))
    n_params = M.param_count(state.params)
    print(f"[train_lm] arch={cfg.name} params={n_params:,} "
          f"optimizer=ZoloMuon({args.method})")
    state = loop.run(state, args.steps)
    print(f"[train_lm] done at step {int(state.step)}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
