"""Paper Algorithm 3 live on 8 (host) devices, through the plan API: the
r subgroup contexts as a ('zolo', 'sep') mesh bound into an SvdPlan at
plan time, with the DGSUM2D combine as psum('zolo').

Also runs the paper-faithful vs gram-shared flop accounting (the
beyond-paper optimization of DESIGN.md §3).

  python examples/distributed_svd.py      (sets its own XLA_FLAGS;
                                           needs `pip install -e .` or
                                           PYTHONPATH=src)
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.core as C  # noqa: E402
import repro.solver as S  # noqa: E402
from repro.dist.grouped import (  # noqa: E402
    grouped_iteration_flops,
    zolo_group_mesh,
)


def main():
    print(f"devices: {len(jax.devices())}")
    rng = np.random.default_rng(5)
    m, n, kappa = 512, 256, 9.06e3  # linverse-class conditioning
    u, _ = np.linalg.qr(rng.standard_normal((m, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    a = jnp.asarray(u @ np.diag(np.geomspace(1, 1 / kappa, n)) @ v.T)

    for r in (2, 4):
        mesh = zolo_group_mesh(r)
        print(f"\nr={r}: mesh = {dict(mesh.shape)}  "
              f"(TOP context = {r} groups, SEP = {8 // r} devices each)")
        # the mesh makes mode resolve to "grouped"; the Zolotarev
        # schedule is precomputed at plan time and the compiled
        # executable is cached per (shape, dtype, config, mesh)
        cfg = S.SvdConfig(method="auto", kappa=kappa,
                          l0_policy="estimate_at_plan")
        p = S.plan(cfg, a.shape, a.dtype, mesh=mesh)
        print(f"  plan: method={p.method} mode={p.mode} r={p.r} "
              f"sep={p.sep} schedule_iters={len(p.schedule)}")
        q, h, info = p.polar(a)
        print(f"  orth={float(C.orthogonality(q)):.2e}  "
              f"rec={float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a)):.2e}")
        # the full grouped SVD (paper Alg. 2 over Alg. 3)
        u_p, s_p, vh_p = p.svd(a)
        s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
        err = float(np.abs(np.asarray(s_p) - s_ref).max())
        print(f"  Zolo-SVD singular-value error vs LAPACK: {err:.2e}")
        # cost model: paper-faithful (per-group Gram) vs gram-shared,
        # and the per-device effect of the intra-group sep distribution
        iters = len(p.schedule)
        faithful = grouped_iteration_flops(m, n, r, iters, False)
        shared = grouped_iteration_flops(m, n, r, iters, True)
        sep_aware = grouped_iteration_flops(m, n, r, iters, False,
                                            sep=p.sep)
        print(f"  flops: paper-faithful={faithful:.3e}  "
              f"gram-shared={shared:.3e}  saving={faithful / shared:.2f}x")
        print(f"  per-device critical path (sep={p.sep}): "
              f"{sep_aware / r:.3e}  "
              f"(plan.flops_estimate={p.flops_estimate:.3e})")

    # --- runtime conditioning: one executable for any kappa ------------
    # l0_policy="runtime" + mesh= resolves to zolo_grouped_dynamic: the
    # sigma_min bound is estimated sep-collectively in-graph and feeds
    # in-graph Zolotarev coefficients, so the SAME compiled plan serves
    # well- and ill-conditioned inputs with zero retraces.
    mesh = zolo_group_mesh(2)
    p_dyn = S.plan(S.SvdConfig(l0_policy="runtime"), a.shape, a.dtype,
                   mesh=mesh)
    print(f"\nruntime-kappa plan: method={p_dyn.method} r={p_dyn.r} "
          f"sep={p_dyn.sep}")
    for kap in (1e2, 1e8):
        u2, _ = np.linalg.qr(rng.standard_normal((m, n)))
        v2, _ = np.linalg.qr(rng.standard_normal((n, n)))
        a2 = jnp.asarray(u2 @ np.diag(np.geomspace(1, 1 / kap, n)) @ v2.T)
        t0 = S.trace_count()
        q, _, info = p_dyn.polar(a2, want_h=False)
        print(f"  kappa={kap:.0e}: orth={float(C.orthogonality(q)):.2e}  "
              f"iters={int(info.iterations)}  "
              f"retraces={S.trace_count() - t0}")


if __name__ == "__main__":
    main()
