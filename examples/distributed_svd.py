"""Paper Algorithm 3 live on 8 (host) devices: the r subgroup contexts as
a ('zolo', 'sep') mesh, with the DGSUM2D combine as psum('zolo').

Also runs the paper-faithful vs gram-shared flop accounting (the
beyond-paper optimization of DESIGN.md §3).

  python examples/distributed_svd.py          (sets its own XLA_FLAGS)
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_ENABLE_X64", "1")

import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.core as C  # noqa: E402
from repro.dist.grouped import (  # noqa: E402
    grouped_iteration_flops,
    grouped_zolo_pd_static,
    zolo_group_mesh,
)


def main():
    print(f"devices: {len(jax.devices())}")
    rng = np.random.default_rng(5)
    m, n, kappa = 512, 256, 9.06e3  # linverse-class conditioning
    u, _ = np.linalg.qr(rng.standard_normal((m, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    a = jnp.asarray(u @ np.diag(np.geomspace(1, 1 / kappa, n)) @ v.T)

    for r in (2, 4):
        mesh = zolo_group_mesh(r)
        print(f"\nr={r}: mesh = {dict(mesh.shape)}  "
              f"(TOP context = {r} groups, SEP = {8 // r} devices each)")
        q = grouped_zolo_pd_static(a, mesh=mesh, l0=0.9 / kappa, r=r)
        h = C.form_h(q, a)
        print(f"  orth={float(C.orthogonality(q)):.2e}  "
              f"rec={float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a)):.2e}")
        # eigendecomposition of H completes the SVD (paper Alg. 2)
        w, vec = jnp.linalg.eigh(h)
        s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
        err = float(np.abs(np.sort(np.asarray(w))[::-1] - s_ref).max())
        print(f"  Zolo-SVD singular-value error vs LAPACK: {err:.2e}")
        # cost model: paper-faithful (per-group Gram) vs gram-shared
        iters = 4 if r == 2 else 3
        faithful = grouped_iteration_flops(m, n, r, iters, False)
        shared = grouped_iteration_flops(m, n, r, iters, True)
        print(f"  flops: paper-faithful={faithful:.3e}  "
              f"gram-shared={shared:.3e}  saving={faithful / shared:.2f}x")


if __name__ == "__main__":
    main()
