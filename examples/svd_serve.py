"""The SVD service end-to-end on 8 (host) devices: a heterogeneous
request stream — tall, wide, two dtypes, two accuracy modes — bucketed
into a padded plan pool, continuously micro-batched, and dispatched with
the batch axis sharded one-matrix-per-device across the mesh.

  python examples/svd_serve.py        (sets its own XLA_FLAGS;
                                       needs `pip install -e .` or
                                       PYTHONPATH=src)
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.solver as S  # noqa: E402
from repro.launch.svd_serve import synth_matrix  # noqa: E402
from repro.serve import ServiceConfig, SvdService  # noqa: E402


def main():
    ndev = len(jax.devices())
    print(f"devices: {ndev}")

    # batch_size == device count: every dispatched micro-batch puts one
    # padded matrix on each device (NamedSharding over the batch axis)
    svc = SvdService(ServiceConfig(batch_size=ndev, max_wait=0.002,
                                   data_axis=tuple(jax.devices())))

    # warm + pin the expected buckets: after this, every request is a
    # plan-cache hit and the stream runs with zero retraces
    shapes = [(96, 64), (40, 100), (120, 80)]
    keys = svc.warmup(shapes, modes=("fast", "standard"),
                      dtypes=("float64", "float32"))
    print(f"warmed {len(keys)} bucket plans "
          f"(cache: {S.cache_stats()['pinned']} pinned)")

    rng = np.random.default_rng(0)
    reqs, futs = [], []
    for i in range(3 * ndev):
        m, n = shapes[int(rng.integers(len(shapes)))]
        dtype = (jnp.float64, jnp.float32)[int(rng.integers(2))]
        mode = ("fast", "standard")[int(rng.integers(2))]
        # stay inside the "fast" mode's kappa-1e2 accuracy contract:
        # out-of-contract requests fail their runtime health check and
        # escalate (correct, but then the stream compiles retry lanes
        # and the zero-retrace claim above would not hold)
        a = synth_matrix(m, n, kappa=1e2, seed=i, dtype=dtype)
        reqs.append((a, mode))
        futs.append(svc.submit(a, mode))   # non-blocking
    svc.poll(force=True)                   # dispatch everything queued

    worst = 0.0
    for (a, mode), fut in zip(reqs, futs):
        u, s, vh = fut.result()            # the only blocking edge
        a64 = a.astype(jnp.float64)
        rec = jnp.linalg.norm(u.astype(jnp.float64) * s.astype(
            jnp.float64)[..., None, :] @ vh.astype(jnp.float64) - a64)
        worst = max(worst, float(rec / jnp.linalg.norm(a64)))
    st = svc.stats()
    print(f"served {st['solves']} solves in {st['batches']} batches "
          f"({ndev} slots each, one matrix per device)")
    print(f"worst reconstruction error: {worst:.2e}")
    print(f"pad waste {st['pad_waste']:.0%}, slot fill "
          f"{st['slot_fill']:.0%}, plan-cache hit rate "
          f"{st['plan_cache_hit_rate']:.0%}, retraces {st['retraces']}")


if __name__ == "__main__":
    main()
