"""Quickstart: Zolo-SVD as a drop-in SVD, validated against jnp.linalg.svd.

  PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.core as C  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    n, kappa = 512, 1e8
    u, _ = np.linalg.qr(rng.standard_normal((n, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    a = jnp.asarray((u * np.geomspace(1, 1 / kappa, n)) @ v.T)
    print(f"matrix: {n}x{n}, kappa={kappa:.0e}")

    # 1. polar decomposition via the paper's Zolo-PD (r chosen per Table 1)
    r = C.choose_r(kappa)
    q, h, info = C.polar_decompose(a, method="zolo", r=r)
    print(f"Zolo-PD: r={r}, iterations={int(info.iterations)}, "
          f"orth={float(C.orthogonality(q)):.2e}, "
          f"|QH-A|/|A|={float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a)):.2e}")

    # 2. full SVD via PD + eigendecomposition (paper Alg. 2)
    u_z, s_z, vh_z = C.polar_svd(a, method="zolo", r=r)
    s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
    print(f"Zolo-SVD: residual={float(C.svd_residual(a, u_z, s_z, vh_z)):.2e}, "
          f"orthU={float(C.orthogonality(u_z)):.2e}, "
          f"max |sigma - ref|={float(np.abs(np.asarray(s_z) - s_ref).max()):.2e}")

    # 3. QDWH baseline (the paper's comparison)
    q2, _, info2 = C.polar_decompose(a, method="qdwh", want_h=False)
    print(f"QDWH-PD: iterations={int(info2.iterations)} "
          f"(Zolo saves {int(info2.iterations) - int(info.iterations)})")


if __name__ == "__main__":
    main()
