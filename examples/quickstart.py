"""Quickstart: plan once, solve many — the ``repro.solver`` plan/execute
API, validated against jnp.linalg.svd.

  PYTHONPATH=src python examples/quickstart.py   (or after pip install -e .)
"""

import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.core as C  # noqa: E402
import repro.solver as S  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    n, kappa = 512, 1e8
    u, _ = np.linalg.qr(rng.standard_normal((n, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    a = jnp.asarray((u * np.geomspace(1, 1 / kappa, n)) @ v.T)
    print(f"matrix: {n}x{n}, kappa={kappa:.0e}")

    # 1. plan: auto method via the registry cost model, r per paper
    #    Table 1, l0 from the conditioning hint, schedule precomputed.
    cfg = S.SvdConfig(method="auto", kappa=kappa,
                      l0_policy="estimate_at_plan")
    p = S.plan(cfg, a.shape, a.dtype)
    print(f"plan: {p}  schedule_iters={len(p.schedule or ())} "
          f"flops~{p.flops_estimate:.2e}")

    # 2. execute: the first call compiles; repeats at this
    #    (shape, dtype, config) hit the cached executable — no retrace.
    u_p, s_p, vh_p = p.svd(a)
    t0 = S.trace_count()
    p.svd(a)
    assert S.trace_count() == t0, "second solve must not retrace"
    s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
    print(f"{p.method}-SVD: "
          f"residual={float(C.svd_residual(a, u_p, s_p, vh_p)):.2e}, "
          f"orthU={float(C.orthogonality(u_p)):.2e}, "
          f"max |sigma - ref|={float(np.abs(np.asarray(s_p) - s_ref).max()):.2e}")

    # 3. the paper's Zolo-PD explicitly, off a second plan, plus the
    #    polar factorization from the same plan object.
    zolo = S.plan(cfg.replace(method="zolo_static"), a.shape, a.dtype)
    q, h, info = zolo.polar(a)
    print(f"Zolo-PD: r={zolo.r}, iterations={int(info.iterations)}, "
          f"orth={float(C.orthogonality(q)):.2e}, "
          f"|QH-A|/|A|={float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a)):.2e}")

    # 4. dynamic QDWH baseline through the drop-in wrapper (the wrapper
    #    rides the same plan path; the estimate is made in-graph).
    q2, _, info2 = C.polar_decompose(a, method="qdwh", want_h=False)
    print(f"QDWH-PD: iterations={int(info2.iterations)} "
          f"(Zolo saves {int(info2.iterations) - int(info.iterations)})")


if __name__ == "__main__":
    main()
