"""Batched serving with prefill + compiled decode loop (KV/state caches).

Shows both a full-attention arch (ring-buffer KV cache) and a
sub-quadratic one (recurrentgemma: RG-LRU state + local window), the two
cache regimes behind the decode_32k / long_500k dry-run shapes.

  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as CFG
from repro.models import model as M
from repro.serve.engine import ServeEngine


def demo(arch: str, batch: int = 4, prompt: int = 64, gen: int = 48):
    cfg = CFG.get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=prompt + gen, temperature=0.8)
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt)), jnp.int32)}
    if cfg.num_prefix_embeds:
        b["embeds"] = jnp.asarray(
            rng.standard_normal((batch, cfg.num_prefix_embeds, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    t0 = time.perf_counter()
    toks, caches = eng.generate(b, steps=gen, key=jax.random.PRNGKey(7))
    toks.block_until_ready()
    dt = time.perf_counter() - t0
    kinds = ",".join(sorted(set(cfg.block_pattern)))
    print(f"[serve] {arch:22s} mixers=({kinds}) batch={batch} "
          f"prompt={prompt} gen={gen}: {batch * gen / dt:7.1f} tok/s "
          f"(incl. compile)")
    return toks


def main():
    demo("qwen3-8b")            # full attention, ring KV cache
    demo("recurrentgemma-2b")   # RG-LRU state + 2048-window local attn
    demo("mamba2-130m")         # pure SSM state
    demo("moonshot-v1-16b-a3b")  # MoE decode


if __name__ == "__main__":
    main()
