"""Partial-spectrum SVD end-to-end: top-k as a first-class workload.

Three views of the same subsystem:

1. ``plan_topk`` directly — the cost model picks the randomized-sketch
   path for k << n and falls back to dense for k ~ n; both plans compile
   once and are cached by (config, shape, dtype).
2. The adaptive wrapper — a-posteriori residual check with automatic
   escalation to the dense plan when the sketch cannot certify the
   requested tolerance.
3. The serving lane — ``mode="topk:<k>"`` requests batch in their own
   buckets of the service's plan pool.

  python examples/svd_topk.py        (needs `pip install -e .` or
                                      PYTHONPATH=src)
"""

import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.spectral as SP  # noqa: E402
from repro.serve import ServiceConfig, SvdService  # noqa: E402


def synth(m, n, kappa, seed=0):
    rng = np.random.default_rng(seed)
    k = min(m, n)
    u, _ = np.linalg.qr(rng.standard_normal((m, k)))
    v, _ = np.linalg.qr(rng.standard_normal((n, k)))
    s = np.geomspace(1.0, 1.0 / kappa, k)
    return jnp.asarray((u * s) @ v.T, dtype=jnp.float64)


def main():
    m, n, k = 1024, 256, 16
    a = synth(m, n, kappa=1e6, seed=0)

    # 1. plan once, solve many: auto picks the sketch for k << n
    plan = SP.plan_topk(SP.TopKConfig(k=k, kappa=1e6), (m, n))
    u, s, vh = plan.topk(a)
    ref = np.linalg.svd(np.asarray(a), compute_uv=False)[:k]
    print(f"plan: strategy={plan.strategy} l={plan.l} "
          f"q_iters={plan.q_iters}")
    print(f"top-{k} values vs dense: max err "
          f"{np.abs(np.asarray(s) - ref).max() / ref[0]:.2e}")
    print(f"factors: u{tuple(u.shape)} s{tuple(s.shape)} "
          f"vh{tuple(vh.shape)}")

    # ... and k ~ n hands the work to the dense path
    near_full = SP.plan_topk(SP.TopKConfig(k=n - 8, kappa=1e6), (m, n))
    print(f"k={n - 8} (~n): strategy={near_full.strategy}")

    # 2. adaptive: residual-certified, escalates only when needed
    u, s, vh, info = plan.topk_adaptive(a)
    print(f"adaptive: residual={info['residual']:.2e} "
          f"escalated={info['escalated']}")

    # 3. the serving lane: topk:<k> buckets in the plan pool
    svc = SvdService(ServiceConfig(batch_size=2, max_wait=0.0))
    svc.warmup([(m, n)], modes=(f"topk:{k}",))
    futs = [svc.submit(synth(m, n, 1e6, seed=i), mode=f"topk:{k}")
            for i in range(4)]
    svc.poll(force=True)
    for fut in futs:
        uk, sk, vhk = fut.result()
        assert uk.shape == (m, k) and vhk.shape == (k, n)
    st = svc.stats()
    print(f"served {st['solves']} topk solves in {st['batches']} "
          f"batches, retraces {st['retraces']}")


if __name__ == "__main__":
    main()
