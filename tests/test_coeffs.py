"""Zolotarev coefficients: paper Table 1 reproduction + identities."""

import numpy as np
import pytest
from _propcheck import given, settings, st

import jax.numpy as jnp
from repro.core import coeffs as C

PAPER_TABLE1 = {
    1: [2, 2, 2, 3, 3, 3, 4, 4, 4, 5, 5, 6],
    2: [1, 2, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4],
    3: [1, 1, 2, 2, 2, 2, 2, 2, 3, 3, 3, 3],
    4: [1, 1, 1, 2, 2, 2, 2, 2, 2, 3, 3, 3],
    5: [1, 1, 1, 1, 2, 2, 2, 2, 2, 2, 3, 3],
    6: [1, 1, 1, 1, 1, 2, 2, 2, 2, 2, 2, 3],
    7: [1, 1, 1, 1, 1, 1, 2, 2, 2, 2, 2, 3],
    8: [1, 1, 1, 1, 1, 1, 2, 2, 2, 2, 2, 2],
}
KAPPAS = [1.001, 1.01, 1.1, 1.2, 1.5, 2, 10, 1e2, 1e3, 1e5, 1e7, 1e16]


def test_table1_reproduction():
    """95/96 cells at tol=1e-15; the (r=7, kappa=2) cell sits exactly on
    the threshold (achieved 1.22e-15) and matches at tol=1.3e-15."""
    mismatches = []
    for r, row in PAPER_TABLE1.items():
        ours = [C.zolo_iter_count(k, r) for k in KAPPAS]
        for k, a, b in zip(KAPPAS, ours, row):
            if a != b:
                mismatches.append((r, k, a, b))
    assert mismatches in ([], [(7, 2, 2, 1)]), mismatches
    # the borderline cell closes at a hair looser tolerance
    assert C.zolo_iter_count(2, 7, tol=1.3e-15) == 1


def test_qdwh_needs_at_most_six():
    # paper §2.2: QDWH requires <= 6 iterations even at kappa = 1e16
    assert C.qdwh_iter_count(1e16) == 6
    assert C.qdwh_iter_count(10) == 4


@given(st.floats(min_value=1e-7, max_value=0.5),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=15, deadline=None)
def test_partial_fraction_equals_product(l, r):
    c, a, mh = C.zolo_coeffs(jnp.float64(l), r)
    x = jnp.linspace(l, 1.0, 9, dtype=jnp.float64)
    f_pf = C.zolo_fn_scalar(x, c, a, mh)
    f_pr = C.zolo_fn_product(x, c, mh)
    np.testing.assert_allclose(np.asarray(f_pf), np.asarray(f_pr),
                               rtol=1e-12)


@given(st.floats(min_value=1e-6, max_value=0.5),
       st.integers(min_value=1, max_value=6))
@settings(max_examples=15, deadline=None)
def test_scaled_function_properties(l, r):
    c, a, mh = C.zolo_coeffs(jnp.float64(l), r)
    # hat-Z(1) = 1 by construction
    f1 = float(C.zolo_fn_scalar(jnp.float64(1.0), c, a, mh))
    assert abs(f1 - 1.0) < 1e-12
    # the l-update equals the function value at l and improves the bound
    l_next = float(C.zolo_l_update(jnp.float64(l), c, mh))
    f_l = float(C.zolo_fn_scalar(jnp.float64(l), c, a, mh))
    assert abs(l_next - f_l) < 1e-12
    assert l_next > l
    # maps [l, 1] into [l_next, ~1+eps] (equioscillation keeps it near 1)
    x = jnp.linspace(l, 1.0, 64, dtype=jnp.float64)
    fx = np.asarray(C.zolo_fn_scalar(x, c, a, mh))
    assert fx.min() >= l_next - 1e-12
    assert fx.max() <= 2.0 - l_next + 1e-12


def test_np_and_jax_backends_agree():
    """In-graph (Landen) vs trace-time (scipy/mpmath) coefficients.

    The JAX Landen recursion loses ~8 digits at extreme moduli (documented
    in core/elliptic.py; self-correcting across composed iterations since
    l is re-derived each step), so the tolerance is regime-dependent."""
    for l, rtol in ((1e-5, 1e-7), (1e-2, 1e-12), (0.3, 1e-12)):
        for r in (2, 3, 5):
            c_np, a_np, m_np = C.zolo_coeffs_np(l, r)
            c_j, a_j, m_j = C.zolo_coeffs(jnp.float64(l), r)
            np.testing.assert_allclose(np.asarray(c_j), c_np, rtol=rtol)
            np.testing.assert_allclose(np.asarray(a_j), a_np, rtol=rtol)
            assert abs(float(m_j) - m_np) < 1e-8


def test_choose_r_prefers_small():
    assert C.choose_r(1.29) in (2, 3)
    assert C.choose_r(9.06e3) in (2, 3)
    assert C.choose_r(3.46e11, max_groups=8) <= 8
