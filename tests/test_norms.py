"""Spectral-bound estimators: accumulation dtype and conditioning range.

Regression coverage for two estimator bugs:

* ``sigma_min_lower`` computed its Gram product without f32-or-better
  accumulation, so a bf16 input's ridge delta = n * eps_bf16 pushed the
  resolution floor to ~sqrt(n * 0.008) — an *over*-estimate of
  sigma_min, which invalidates the Zolotarev interval it feeds.
* ``condition_estimate`` went through the Gram-route ``sigma_min_lower``,
  which squares the condition number and floors out near sqrt(n * eps),
  silently capping kappa estimates around 1e7 in f64.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import norms

from conftest import make_matrix


def test_sigma_min_lower_bf16_accumulates_f32():
    """bf16 input: the estimate must stay a *lower* bound of sigma_min
    (the old bf16 ridge made it an over-estimate ~0.3 for sigma_min 0.1)
    and must not collapse to the bf16 resolution floor."""
    a = make_matrix(64, 48, 10.0, dtype=jnp.bfloat16)  # sigma_min = 0.1
    est = norms.sigma_min_lower(a)
    assert est.dtype == jnp.float32  # promoted accumulation dtype
    assert float(est) <= 0.105  # lower bound (0.5 safety; bf16 noise slack)
    assert float(est) >= 0.02   # resolves well above the old ~0.3 floor


def test_sigma_min_lower_f64_path_unchanged():
    """f64/f32 inputs already promote to themselves: same estimator."""
    a = make_matrix(96, 64, 1e3)  # f64, sigma_min = 1e-3
    est = float(norms.sigma_min_lower(a))
    assert 2.5e-4 <= est <= 1e-3  # ~0.5 * sigma_min, never above


@pytest.mark.parametrize("kappa", [1e4, 1e10, 1e13])
def test_condition_estimate_known_kappa(kappa):
    """QR-routed kappa estimate: an over-estimate of the true kappa_2,
    within a small factor — including regimes far beyond the Gram
    route's ~1e7 squaring floor."""
    a = make_matrix(96, 64, kappa, seed=3)
    est = float(norms.condition_estimate(a))
    assert est >= 0.99 * kappa          # over-estimate (fp slack)
    assert est <= 20.0 * kappa          # ...but a usable one


def test_condition_estimate_bf16_promotes():
    """The QR route has no bf16 kernel; the estimator must promote to
    f32 up front instead of raising, and still bound kappa from above."""
    kappa = 50.0
    a = make_matrix(64, 48, kappa, dtype=jnp.bfloat16, seed=6)
    est = float(norms.condition_estimate(a))
    assert est >= 0.9 * kappa   # bf16 rounding slack on the input itself
    assert est <= 20.0 * kappa


def test_condition_estimate_beats_gram_floor():
    """The old Gram route capped near 1/ (0.5 * sqrt(n * eps)) ~ 2e7 in
    f64; the QR route must keep tracking kappa past that."""
    a = make_matrix(96, 64, 1e12, seed=4)
    est = float(norms.condition_estimate(a))
    assert est > 1e11
