"""SVD serving subsystem: bucketing exactness, scheduler policy, service
futures, and the zero-retrace / 100%-hit-rate steady-state contract."""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as C
import repro.solver as S
from repro.serve import (
    BucketKey,
    BucketPolicy,
    MicroBatchScheduler,
    ServiceConfig,
    SvdService,
)
from repro.serve.bucketing import pad_waste

from conftest import make_matrix, run_multidevice_script


# --- bucketing policy --------------------------------------------------------


def test_bucket_ladder_is_geometric():
    pol = BucketPolicy(base=32, growth=1.5)
    assert [pol.rung(s) for s in (1, 32, 33, 48, 49, 100, 150)] == \
        [32, 32, 48, 48, 72, 108, 162]
    # monotone, and never below the request size
    for s in range(1, 400, 7):
        assert pol.rung(s) >= s
        assert pol.rung(s + 1) >= pol.rung(s)


def test_bucket_key_orientation_free():
    pol = BucketPolicy()
    k1 = pol.key_for((40, 100), jnp.float64, "standard")
    k2 = pol.key_for((100, 40), jnp.float64, "standard")
    assert k1 == k2 == BucketKey(108, 48, "float64", "standard")
    # dtype and mode are key dimensions: distinct executables
    assert pol.key_for((40, 100), jnp.float32, "standard") != k1
    assert pol.key_for((40, 100), jnp.float64, "fast") != k1


def test_bucket_policy_validates():
    with pytest.raises(ValueError, match="growth"):
        BucketPolicy(growth=1.0)
    with pytest.raises(ValueError, match="base"):
        BucketPolicy(base=0)
    with pytest.raises(ValueError, match=">= 1"):
        BucketPolicy().rung(0)


def test_pad_waste_accounting():
    # one exact-fit matrix in a 1-slot bucket: zero waste
    assert pad_waste([(48, 32)], 48, 32, 1) == 0.0
    # empty slots are pure waste
    assert pad_waste([(48, 32)], 48, 32, 2) == pytest.approx(0.5)
    # orientation-free useful-element count
    assert pad_waste([(32, 48)], 48, 32, 1) == 0.0


# --- padded-solve exactness across the ladder --------------------------------


@pytest.mark.parametrize("shape", [(96, 64), (33, 97), (48, 48), (100, 40),
                                   (108, 72), (7, 5)])
def test_padded_solve_matches_unpadded(shape):
    """The tentpole exactness claim: a bucketed (padded rows+cols,
    masked-out) solve equals the direct solve to tier-1 tolerance, for
    tall, wide, square, exact-fit, and tiny shapes."""
    m, n = shape
    kappa = 1e3
    a = make_matrix(m, n, kappa, seed=m * 100 + n)
    svc = SvdService(ServiceConfig(batch_size=2, max_wait=0.0))
    fut = svc.submit(a, mode="standard")
    u, s, vh = fut.result()
    k = min(m, n)
    assert u.shape == (m, k) and s.shape == (k,) and vh.shape == (k, n)
    s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s_ref, atol=1e-11)
    assert float(C.svd_residual(a, u, s, vh)) < 5e-12
    assert float(C.orthogonality(u)) < 1e-11
    assert float(C.orthogonality(vh.T)) < 1e-11


def test_padded_solve_bf16():
    """bf16 requests route through an f32 compute plan and come back in
    bf16, still correct to bf16 resolution."""
    a = make_matrix(60, 40, 1e2, dtype=jnp.bfloat16, seed=3)
    svc = SvdService(ServiceConfig(batch_size=2, max_wait=0.0))
    u, s, vh = svc.submit(a, mode="fast").result()
    assert u.dtype == s.dtype == vh.dtype == jnp.bfloat16
    a64 = a.astype(jnp.float64)
    rec = (u.astype(jnp.float64) * s.astype(jnp.float64)[None, :]
           ) @ vh.astype(jnp.float64)
    err = float(jnp.linalg.norm(rec - a64) / jnp.linalg.norm(a64))
    assert err < 5e-2


# --- scheduler policy --------------------------------------------------------


def _fake_clock(t0=0.0):
    state = {"t": t0}

    def clock():
        return state["t"]

    clock.advance = lambda dt: state.__setitem__("t", state["t"] + dt)
    return clock


def test_scheduler_full_batches_never_wait():
    clk = _fake_clock()
    sched = MicroBatchScheduler(2, max_wait=10.0, clock=clk)
    sched.enqueue("k", "a")
    assert sched.ready() == []          # partial, head not aged
    sched.enqueue("k", "b")
    assert sched.ready() == [("k", ["a", "b"])]   # full: immediate
    assert sched.pending() == 0


def test_scheduler_partial_flush_by_head_age_no_starvation():
    """A rare bucket is flushed by its head's age even while a hot
    bucket keeps filling — no request starves behind traffic it does
    not share a bucket with."""
    clk = _fake_clock()
    sched = MicroBatchScheduler(4, max_wait=0.01, clock=clk)
    sched.enqueue("rare", "r0")
    rare_flushed_at = None
    for burst in range(3):
        for i in range(4):
            sched.enqueue("hot", f"h{burst}{i}")
        clk.advance(0.004)
        batches = sched.ready()
        assert ("hot", [f"h{burst}{i}" for i in range(4)]) in batches
        if ("rare", ["r0"]) in batches and rare_flushed_at is None:
            rare_flushed_at = clk()
    # flushed by head age — after max_wait, regardless of hot traffic
    assert rare_flushed_at is not None and rare_flushed_at >= 0.01
    assert sched.pending() == 0


def test_scheduler_oldest_head_first_and_burst_drain():
    clk = _fake_clock()
    sched = MicroBatchScheduler(2, max_wait=0.0, clock=clk)
    sched.enqueue("b", "b0")
    clk.advance(0.001)
    for item in ("a0", "a1", "a2", "a3", "a4"):
        sched.enqueue("a", item)
    got = sched.ready()
    # bucket "b" has the oldest head -> dispatches first; bucket "a"
    # drains two full batches plus the aged partial in one call
    assert got == [("b", ["b0"]), ("a", ["a0", "a1"]),
                   ("a", ["a2", "a3"]), ("a", ["a4"])]


def test_scheduler_force_flush():
    sched = MicroBatchScheduler(4, max_wait=100.0, clock=_fake_clock())
    sched.enqueue("k", "x")
    assert sched.ready() == []
    assert sched.ready(force=True) == [("k", ["x"])]


def test_scheduler_validates():
    with pytest.raises(ValueError, match="batch_size"):
        MicroBatchScheduler(0)
    with pytest.raises(ValueError, match="max_wait"):
        MicroBatchScheduler(1, max_wait=-1.0)


# --- service: futures, ordering, steady state --------------------------------


def test_futures_resolve_in_submission_order_per_bucket():
    """FIFO within a bucket: each future's result reconstructs its own
    matrix (no slot permutation), and completion order follows
    submission order."""
    kappa = 1e3
    svc = SvdService(ServiceConfig(batch_size=2, max_wait=0.0))
    mats = [make_matrix(40, 30, kappa, seed=s) for s in range(5)]
    futs = [svc.submit(a) for a in mats]
    assert svc.pending() == 5
    svc.poll(force=True)
    assert svc.pending() == 0
    for a, fut in zip(mats, futs):
        u, s, vh = fut.result()
        assert float(C.svd_residual(a, u, s, vh)) < 5e-12
    seqs = [f.seq for f in futs]
    assert seqs == sorted(seqs)
    done = [f.t_done for f in futs]
    assert done == sorted(done)


def test_mixed_stream_zero_retraces_full_hit_rate():
    """The acceptance contract: after warmup over the expected shape
    set, a mixed-shape/mode stream runs at 100% plan-cache hit rate
    with zero retraces."""
    shapes = [(96, 64), (40, 100), (64, 48)]
    svc = SvdService(ServiceConfig(batch_size=4, max_wait=0.0))
    svc.warmup(shapes, modes=("fast", "standard"), dtypes=("float64",))
    rng = np.random.default_rng(0)
    futs = []
    for i in range(17):   # not a batch multiple: exercises empty slots
        m, n = shapes[int(rng.integers(len(shapes)))]
        mode = ("fast", "standard")[int(rng.integers(2))]
        futs.append(svc.submit(make_matrix(m, n, 1e2, seed=i), mode))
    svc.flush()
    assert all(f.done() for f in futs)
    st = svc.stats()
    assert st["solves"] == 17
    assert st["plan_cache_hit_rate"] == 1.0
    assert st["retraces"] == 0
    assert 0.0 < st["pad_waste"] < 1.0
    assert st["pending"] == 0 and st["inflight"] == 0


def test_warmup_pins_buckets_against_eviction():
    svc = SvdService(ServiceConfig(batch_size=2, max_wait=0.0))
    keys = svc.warmup([(48, 32)], modes=("standard",),
                      dtypes=("float64",))
    assert len(keys) == 1
    prev = S.set_plan_cache_capacity(1)
    try:
        # churn the cache well past capacity
        for k in (1e2, 1e3, 1e4):
            S.plan(S.SvdConfig(method="zolo_static", l0=0.9 / k),
                   (30, 20), jnp.float64)
        assert S.cache_stats()["evictions"] >= 2
        fut = svc.submit(make_matrix(48, 32, 1e3, seed=1))
        before = S.cache_stats()
        svc.poll(force=True)
        fut.result()
        after = S.cache_stats()
        # the dispatch re-looked its bucket plan up and HIT: the pin
        # held through eviction pressure far past capacity
        assert after["misses"] == before["misses"]
        assert after["hits"] == before["hits"] + 1
    finally:
        S.set_plan_cache_capacity(prev)


def test_service_validates_requests():
    svc = SvdService(ServiceConfig())
    with pytest.raises(ValueError, match="accuracy mode"):
        svc.submit(jnp.zeros((4, 4)), mode="nope")
    with pytest.raises(ValueError, match="one .m, n. matrix"):
        svc.submit(jnp.zeros((2, 4, 4)))
    with pytest.raises(ValueError, match="does not divide"):
        SvdService(ServiceConfig(batch_size=3,
                                 data_axis=("d0", "d1")))


def test_latency_stamps():
    clk = _fake_clock()
    svc = SvdService(ServiceConfig(batch_size=1, max_wait=0.0), clock=clk)
    fut = svc.submit(make_matrix(16, 16, 1e2, seed=0))
    clk.advance(0.25)
    svc.poll()
    fut.result()
    assert fut.done()
    assert fut.latency == pytest.approx(0.25)


def test_service_multidevice_data_sharded():
    """batch_size == ndev with data_axis: one padded matrix per device,
    same exactness, zero retraces (subprocess: XLA device count is fixed
    at jax import)."""
    script = """
import numpy as np
import jax, jax.numpy as jnp
import repro.core as C
from repro.launch.svd_serve import synth_matrix
from repro.serve import ServiceConfig, SvdService

svc = SvdService(ServiceConfig(batch_size=8, max_wait=0.0,
                               data_axis=tuple(jax.devices())))
svc.warmup([(48, 32)], modes=("standard",), dtypes=("float64",))
mats = [synth_matrix(48, 32, 1e3, seed=s) for s in range(8)]
futs = [svc.submit(a) for a in mats]
svc.poll(force=True)
worst = max(float(C.svd_residual(a, *f.result()))
            for a, f in zip(mats, futs))
st = svc.stats()
assert worst < 5e-12, worst
assert st["retraces"] == 0 and st["plan_cache_hit_rate"] == 1.0, st
print("SHARDED_SERVE_OK", worst)
"""
    run_multidevice_script(script, "SHARDED_SERVE_OK")


# --- per-bucket max_wait overrides -------------------------------------------


def test_scheduler_per_key_max_wait_override():
    """An overridden bucket flushes at its own age threshold; other
    buckets keep the global default — fake-clock, no real sleeping."""
    clk = _fake_clock()
    sched = MicroBatchScheduler(4, max_wait=1.0, clock=clk)
    sched.set_max_wait("fast", 0.01)
    assert sched.max_wait_for("fast") == 0.01
    assert sched.max_wait_for("slow") == 1.0
    sched.enqueue("fast", "f0")
    sched.enqueue("slow", "s0")
    clk.advance(0.02)
    # past the override but far from the default: only "fast" flushes
    assert sched.ready() == [("fast", ["f0"])]
    assert sched.pending() == 1
    clk.advance(1.0)
    assert sched.ready() == [("slow", ["s0"])]
    # None restores the default
    sched.set_max_wait("fast", None)
    assert sched.max_wait_for("fast") == 1.0
    with pytest.raises(ValueError, match="max_wait"):
        sched.set_max_wait("fast", -1.0)


def test_service_mode_wait_override():
    """ServiceConfig.max_wait_overrides maps a mode tag to its own
    partial-dispatch age; unlisted modes keep the global default."""
    clk = _fake_clock()
    svc = SvdService(ServiceConfig(batch_size=4, max_wait=10.0,
                                   max_wait_overrides=(("fast", 0.0),)),
                     clock=clk)
    f_fast = svc.submit(make_matrix(24, 16, 1e2, seed=0), mode="fast")
    f_std = svc.submit(make_matrix(24, 16, 1e2, seed=1), mode="standard")
    clk.advance(0.001)
    svc.poll()
    assert f_fast.dispatched and not f_std.dispatched
    svc.poll(force=True)
    assert f_std.dispatched


# --- rank-deficient unpadding ------------------------------------------------


def _rankdef(m, n, kappa, rank, seed=0):
    a = np.asarray(make_matrix(m, n, kappa, seed=seed))
    u, s, vh = np.linalg.svd(a, full_matrices=False)
    s[rank:] = 0.0
    return jnp.asarray(u @ np.diag(s) @ vh)


@pytest.mark.parametrize("shape,rank", [((100, 40), 10), ((40, 100), 10),
                                        ((40, 40), 5)])
def test_rank_deficient_padded_round_trip(shape, rank):
    """A rank-deficient request through a padded bucket: genuine
    triplets must be selected by padded index, not by (tied zero)
    value — the eig-side factor stays an orthonormal basis of the
    request's row/column space and reconstruction is exact."""
    svc = SvdService(ServiceConfig(batch_size=1, max_wait=0.0))
    a = _rankdef(*shape, 1e3, rank, seed=2)
    fut = svc.submit(a)
    svc.poll(force=True)
    u, s, vh = map(np.asarray, fut.result())
    m, n = shape
    nmin = min(m, n)
    assert u.shape == (m, nmin) and s.shape == (nmin,)
    assert vh.shape == (nmin, n)
    # The basis that comes from the symmetric eig is orthonormal even
    # at zero singular values; the polar-route partner factor (U = Q V,
    # rank(Q) = rank(A)) has exactly-zero columns there.  For a tall
    # request the eig side is V (returned vh); the wide path solves the
    # transpose, so the swap lands it in u.  No injected zero-column
    # vector (zero everywhere the request lives) may leak past the mask.
    if m >= n:
        assert np.linalg.norm(vh @ vh.T - np.eye(nmin)) < 1e-10
    else:
        assert np.linalg.norm(u.T @ u - np.eye(nmin)) < 1e-10
        assert np.linalg.norm(vh[:rank] @ vh[:rank].T
                              - np.eye(rank)) < 1e-10
    # spectrum and reconstruction match the direct (unpadded) solve
    ref = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(s, ref, atol=1e-10)
    assert np.linalg.norm(np.asarray(a) - (u * s) @ vh) < 1e-10


# --- the topk:<k> serving lane -----------------------------------------------


def test_topk_mode_parse():
    from repro.serve import topk_mode_k

    assert topk_mode_k("topk:16") == 16
    assert topk_mode_k("standard") is None
    with pytest.raises(ValueError, match="topk"):
        topk_mode_k("topk:0")
    with pytest.raises(ValueError, match="topk"):
        topk_mode_k("topk:banana")


def test_topk_lane_end_to_end():
    """topk:<k> requests batch in their own buckets and come back as
    (m, k)/(k,)/(k, n) factors matching the dense leading spectrum."""
    import repro.spectral as SP

    svc = SvdService(ServiceConfig(batch_size=2, max_wait=0.0))
    svc.warmup([(100, 40)], modes=("topk:4",))
    tall = make_matrix(100, 40, 1e3, seed=3)
    wide = make_matrix(30, 90, 1e3, seed=4)
    f_tall = svc.submit(tall, mode="topk:4")
    f_wide = svc.submit(wide, mode="topk:4")
    svc.poll(force=True)
    for a, fut in ((tall, f_tall), (wide, f_wide)):
        u, s, vh = map(np.asarray, fut.result())
        m, n = a.shape
        assert u.shape == (m, 4) and s.shape == (4,)
        assert vh.shape == (4, n)
        ref = np.linalg.svd(np.asarray(a), compute_uv=False)[:4]
        np.testing.assert_allclose(s, ref, atol=1e-10 * ref[0])
    # distinct k at one rung = distinct bucket (k is a shape parameter)
    key4 = svc.policy.key_for((100, 40), jnp.float64, "topk:4")
    key8 = svc.policy.key_for((100, 40), jnp.float64, "topk:8")
    assert key4 != key8


def test_topk_lane_steady_state_zero_retraces():
    svc = SvdService(ServiceConfig(batch_size=2, max_wait=0.0))
    svc.warmup([(64, 32)], modes=("topk:4",))
    for seed in range(4):
        fut = svc.submit(make_matrix(60, 30, 1e3, seed=seed),
                         mode="topk:4")
        svc.poll(force=True)
        fut.result()
    st = svc.stats()
    assert st["retraces"] == 0, st
    assert st["solves"] == 4


def test_topk_lane_validates_k():
    svc = SvdService(ServiceConfig())
    with pytest.raises(ValueError, match="triplets"):
        svc.submit(jnp.zeros((16, 8)), mode="topk:12")
