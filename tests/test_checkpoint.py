"""Checkpoint manager: atomicity, integrity, resharding, loop resume."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.checkpoint.manager import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "stages": (jnp.arange(12.0).reshape(3, 4),)},
            "step": jnp.int32(7)}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = _state()
    mgr.save(7, state)
    restored, step = mgr.restore(state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_k=2, async_save=False)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = _state()
    mgr.save(1, state)
    # flip bytes in one leaf
    d = os.path.join(str(tmp_path), "step_1")
    victim = os.path.join(d, "00000.npy")
    arr = np.load(victim)
    arr_flat = arr.reshape(-1)
    arr_flat[0] += 1.0
    np.save(victim, arr)
    with pytest.raises(IOError):
        mgr.restore(state)


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    state = _state()
    mgr.save(3, state)
    mgr.wait()
    restored, step = mgr.restore(state)
    assert step == 3


_RESHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_ENABLE_X64"] = "1"
import sys
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
sys.path.insert(0, "src")
from repro.checkpoint.manager import CheckpointManager

ckpt_dir = sys.argv[1]
mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
w = jnp.arange(64.0).reshape(8, 8)
state = {"w": jax.device_put(w, sh), "step": jnp.int32(1)}
mgr = CheckpointManager(ckpt_dir, async_save=False)
mgr.save(1, state)
# restore onto a DIFFERENT mesh shape (elastic restart simulation)
mesh2 = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
sh2 = NamedSharding(mesh2, P("model", "data"))
target = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float64, sharding=sh2),
          "step": jnp.int32(0)}
restored, step = mgr.restore(target)
assert step == 1
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
assert restored["w"].sharding.spec == sh2.spec
print("RESHARD_OK")
"""


def test_elastic_resharding_subprocess(tmp_path):
    """Save sharded on a 4x2 mesh, restore onto 2x4 with a different spec
    — the elastic-restart path (runs in a subprocess to get 8 devices)."""
    out = subprocess.run(
        [sys.executable, "-c", _RESHARD_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, cwd=os.getcwd(), timeout=300)
    assert "RESHARD_OK" in out.stdout, out.stdout + out.stderr


def test_loop_resume(tmp_path):
    """TrainLoop resumes from the latest checkpoint step."""
    from repro import configs as CFG
    from repro.data.pipeline import SyntheticLM
    from repro.optim.muon import MuonConfig
    from repro.train.loop import TrainLoop
    from repro.train.step import make_train_step

    cfg = CFG.get_smoke_config("olmo-1b")
    init_fn, step_fn = make_train_step(cfg, MuonConfig(lr=0.01))
    data = SyntheticLM(cfg.vocab_size, 32, 2, dtype=cfg.dtype)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    loop = TrainLoop(step_fn, data, ckpt=mgr, ckpt_every=2, log_every=100)
    state = loop.resume_or_init(init_fn, jax.random.PRNGKey(0))
    state = loop.run(state, 4)
    assert int(state.step) == 4
    # simulate preemption: fresh process-equivalent, must resume at 4
    loop2 = TrainLoop(step_fn, data, ckpt=mgr, ckpt_every=2, log_every=100)
    state2 = loop2.resume_or_init(init_fn, jax.random.PRNGKey(0))
    assert int(state2.step) == 4
    state2 = loop2.run(state2, 6)
    assert int(state2.step) == 6
