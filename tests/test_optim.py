"""ZoloMuon optimizer + gradient compression."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.optim import compression as CP
from repro.optim.muon import MuonConfig, ZoloMuon, muon_labels, orthogonalize

from conftest import make_matrix


@pytest.mark.parametrize("method", ["zolo", "qdwh", "ns5"])
@pytest.mark.parametrize("shape", [(64, 64), (96, 48), (48, 96),
                                   (3, 64, 80)])
def test_orthogonalize_matches_msign(method, shape, rng):
    m = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    o = orthogonalize(m, method=method)
    m2 = np.asarray(m, np.float64).reshape(-1, *shape[-2:])
    o2 = np.asarray(o, np.float64).reshape(-1, *shape[-2:])
    # ns5 maps singular values into ~[0.7, 1.2] by design (Muon does not
    # need exact orthogonality); zolo/qdwh deliver near-exact polar factors
    tol = 0.35 if method == "ns5" else 2e-3
    for mm, oo in zip(m2, o2):
        u, _, vt = np.linalg.svd(mm, full_matrices=False)
        np.testing.assert_allclose(oo, u @ vt, atol=tol)


def test_zolo_tighter_than_ns5(rng):
    """The paper-powered orthogonalization should beat Newton-Schulz-5 on
    orthogonality error at similar iteration depth."""
    m = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)

    def orth_err(o):
        g = np.asarray(o.T @ o, np.float64)
        return np.abs(g - np.eye(96)).max()

    e_zolo = orth_err(orthogonalize(m, "zolo"))
    e_ns5 = orth_err(orthogonalize(m, "ns5"))
    assert e_zolo < e_ns5


def test_muon_labels_rules():
    from repro import configs as CFG
    from repro.models import model as M
    cfg = CFG.get_smoke_config("qwen3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    labels = muon_labels(params, min_dim=16)
    flat = jax.tree_util.tree_flatten_with_path(labels)[0]
    by_name = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in p): v for p, v in flat}
    assert by_name["embed"] is False
    assert by_name["lm_head"] is False
    assert any("wq" in k and v for k, v in by_name.items())
    assert all(not v for k, v in by_name.items() if "norm" in k)


def test_muon_step_descends(rng):
    """ZoloMuon on a quadratic: loss decreases monotonically-ish."""
    w_true = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    y = x @ w_true
    params = {"w": jnp.zeros((64, 64), jnp.float32)}

    def loss_fn(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    opt = ZoloMuon(MuonConfig(lr=0.3, method="zolo"), muon_labels(params))
    state = opt.init(params)
    losses = []
    for _ in range(40):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
        losses.append(float(loss_fn(params)))
    # Muon takes fixed-spectral-norm steps: strong descent, but it may
    # orbit the optimum once close (no per-coordinate damping)
    assert min(losses) < 0.2 * losses[0]
    assert losses[-1] < 0.5 * losses[0]


def test_compression_error_feedback(rng):
    """Error feedback makes the compressed stream unbiased over time:
    sum of decompressed == sum of raw gradients minus the residual."""
    g_list = [jnp.asarray(rng.standard_normal((32, 48)), jnp.float32)
              for _ in range(5)]
    st = CP.init_compression_state(g_list[0], rank=4,
                                   key=jax.random.PRNGKey(0))
    err, q = st["err"], st["q"]
    total_hat = jnp.zeros_like(g_list[0])
    for g in g_list:
        g_hat, err, q = CP.compress_decompress(g, err, q, rank=4)
        total_hat = total_hat + g_hat
    total = sum(g_list)
    np.testing.assert_allclose(np.asarray(total_hat + err),
                               np.asarray(total), atol=1e-3)


def test_compression_exact_for_lowrank(rng):
    """A gradient of rank <= k is transmitted exactly (after the subspace
    warms up)."""
    u = jnp.asarray(rng.standard_normal((40, 3)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((24, 3)), jnp.float32)
    g = u @ v.T
    st = CP.init_compression_state(g, rank=4, key=jax.random.PRNGKey(1))
    err, q = st["err"], st["q"]
    for _ in range(3):
        g_hat, err, q = CP.compress_decompress(g, err, q, rank=4)
    assert float(jnp.abs(g_hat - g).max()) < 1e-4


def test_lowrank_truncate_through_topk_plan():
    """One-shot truncation routes through repro.spectral and is the
    Eckart-Young optimum: error at rank r equals the dense sigma_{r+1}
    tail, monotonically shrinking as rank grows."""
    a = make_matrix(96, 48, 1e4, seed=21)
    ref = np.linalg.svd(np.asarray(a), compute_uv=False)
    prev = np.inf
    for rank in (2, 4, 8, 16):
        p, q = CP.lowrank_truncate(a, rank, kappa=1e4)
        assert p.shape == (96, rank) and q.shape == (48, rank)
        err = np.linalg.norm(np.asarray(a) - np.asarray(p) @ np.asarray(q).T, 2)
        # optimal rank-r 2-norm error is sigma_{r+1}
        assert err <= ref[rank] * (1 + 1e-6) + 1e-10 * ref[0]
        assert err <= prev
        prev = err


def test_lowrank_truncate_batched():
    mats = jnp.stack([make_matrix(64, 32, 1e3, seed=s) for s in (1, 2)])
    p, q = CP.lowrank_truncate(mats, 4, kappa=1e3)
    assert p.shape == (2, 64, 4) and q.shape == (2, 32, 4)
    for i in range(2):
        ref = np.linalg.svd(np.asarray(mats[i]), compute_uv=False)
        err = np.linalg.norm(
            np.asarray(mats[i]) - np.asarray(p[i]) @ np.asarray(q[i]).T, 2)
        assert err <= ref[4] * (1 + 1e-6) + 1e-10 * ref[0]
