"""repro.solver plan/execute API: SvdConfig -> SvdPlan, caching, auto mode."""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as C
import repro.solver as S
from repro.core import registry

from conftest import make_matrix


def test_plan_svd_matches_reference():
    kappa = 1e4
    a = make_matrix(96, 64, kappa, seed=1)
    cfg = S.SvdConfig(method="zolo_static", l0=0.9 / kappa, r=2)
    p = S.plan(cfg, a.shape, a.dtype)
    assert p.method == "zolo_static" and p.mode == "static" and p.r == 2
    assert p.schedule is not None and len(p.schedule) >= 1
    u, s, vh = p.svd(a)
    s0 = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-12)
    assert float(C.svd_residual(a, u, s, vh)) < 5e-13
    assert float(C.orthogonality(u)) < 1e-13


def test_plan_polar_matches_reference():
    kappa = 1e3
    a = make_matrix(80, 48, kappa, seed=2)
    p = S.plan(S.SvdConfig(method="zolo_static", l0=0.9 / kappa),
               a.shape, a.dtype)
    q, h, info = p.polar(a)
    assert int(info.iterations) == len(p.schedule)
    assert float(C.orthogonality(q)) < 1e-13
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert rec < 1e-12


def test_plan_identity_and_zero_retrace():
    """The repeated-solve contract: identical (shape, dtype, config) hits
    the same plan object and the second execution performs no retrace."""
    kappa = 1e4
    cfg = S.SvdConfig(method="zolo_static", l0=0.9 / kappa, r=2)
    p1 = S.plan(cfg, (96, 64), jnp.float64)
    p2 = S.plan(S.SvdConfig(method="zolo_static", l0=0.9 / kappa, r=2),
                (96, 64), jnp.float64)
    assert p1 is p2

    a = make_matrix(96, 64, kappa, seed=3)
    b = make_matrix(96, 64, kappa, seed=4)
    u1, s1, _ = p1.svd(a)  # may compile
    t0 = S.trace_count()
    u2, s2, _ = p1.svd(b)
    assert S.trace_count() == t0, "second plan.svd call retraced"
    # re-planning the same config must reuse the cached executable too
    p3 = S.plan(cfg, (96, 64), jnp.float64)
    p3.svd(a)
    assert S.trace_count() == t0
    np.testing.assert_allclose(
        np.asarray(s2), np.linalg.svd(np.asarray(b), compute_uv=False),
        atol=1e-12)


def test_plan_polar_no_retrace_and_distinct_want_h():
    kappa = 1e3
    cfg = S.SvdConfig(method="qdwh_static", l0=0.9 / kappa)
    p = S.plan(cfg, (64, 48), jnp.float64)
    a = make_matrix(64, 48, kappa, seed=5)
    q1, h1, _ = p.polar(a)
    t0 = S.trace_count()
    q2, h2, _ = p.polar(a)
    assert S.trace_count() == t0
    qn, hn, _ = p.polar(a, want_h=False)  # separate executable
    assert hn is None and h1 is not None
    assert S.trace_count() == t0 + 1
    p.polar(a, want_h=False)
    assert S.trace_count() == t0 + 1


def test_auto_mode_runtime_l0_picks_dynamic():
    """l0_policy='runtime' -> a dynamic (in-graph conditioning) backend."""
    cfg = S.SvdConfig(l0_policy="runtime")
    p = S.plan(cfg, (64, 48), jnp.float64)
    assert p.mode == "dynamic"
    assert registry.get_polar(p.method).dynamic
    a = make_matrix(64, 48, 1e3, seed=6)
    u, s, vh = p.svd(a)
    np.testing.assert_allclose(
        np.asarray(s), np.linalg.svd(np.asarray(a), compute_uv=False),
        atol=1e-11)


def test_auto_dynamic_square_skips_baselines():
    """Square problems must not auto-select the Newton comparison
    baseline (explicit matrix inverses); baselines are explicit-only."""
    p = S.plan(S.SvdConfig(l0_policy="runtime"), (96, 96), jnp.float64)
    spec = registry.get_polar(p.method)
    assert spec.dynamic and not spec.baseline and not spec.is_oracle
    a = make_matrix(96, 96, 1e8, seed=11)
    u, s, vh = p.svd(a)
    assert float(C.svd_residual(a, u, s, vh)) < 5e-13
    # newton remains reachable explicitly
    q, h, info = C.polar_decompose(a, method="newton")
    assert float(C.orthogonality(q)) < 1e-12


def test_auto_mode_mesh_picks_grouped():
    """mesh= -> grouped mode and a grouped-capable method; r == ndev
    (sep axis of size 1) runs on single-device CI."""
    from repro.dist import zolo_group_mesh

    mesh = zolo_group_mesh(1)  # 1 group x all devices of this process
    cfg = S.SvdConfig(kappa=1e3, l0_policy="estimate_at_plan", r=1)
    p = S.plan(cfg, (64, 32), jnp.float64, mesh=mesh)
    assert p.mode == "grouped"
    assert registry.get_polar(p.method).supports_grouped
    assert p.r == 1 and p.l0 == pytest.approx(0.9e-3)
    a = make_matrix(64, 32, 1e3, seed=7)
    q, h, info = p.polar(a)
    assert float(C.orthogonality(q)) < 1e-13
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert rec < 1e-12


def test_auto_static_selects_by_cost_model():
    cfg = S.SvdConfig(kappa=1e8, l0_policy="estimate_at_plan")
    p = S.plan(cfg, (128, 96), jnp.float64)
    assert p.mode == "static"
    spec = registry.get_polar(p.method)
    assert not spec.dynamic and not spec.is_oracle
    assert p.flops_estimate is not None and p.flops_estimate > 0
    # the pick is the flops_fn argmin over static-capable backends
    others = [registry.get_polar(n) for n in registry.list_polar()]
    for s in others:
        if s.is_oracle or s.dynamic or s.requires_mesh or s.flops_fn is None:
            continue
        assert p.flops_estimate <= float(
            s.flops_fn(128, 96, r=p.r, kappa=1e8)) * (1 + 1e-12)


def test_svd_batched_reuses_one_executable():
    kappa = 1e3
    cfg = S.SvdConfig(method="zolo_static", l0=0.9 / kappa, r=2)
    p = S.plan(cfg, (48, 32), jnp.float64)
    a = jnp.stack([make_matrix(48, 32, kappa, seed=s) for s in (1, 2, 3)])
    u, s, vh = p.svd_batched(a)
    assert u.shape == (3, 48, 32) and s.shape == (3, 32)
    t0 = S.trace_count()
    p.svd_batched(a)
    assert S.trace_count() == t0
    for i in range(3):
        s0 = np.linalg.svd(np.asarray(a[i]), compute_uv=False)
        np.testing.assert_allclose(np.asarray(s[i]), s0, atol=1e-12)


def test_unscaled_input_safe_by_default():
    """The default scale='power' makes static plans correct for
    un-normalized inputs (the documented flagship path)."""
    kappa = 1e4
    a = 5.0 * make_matrix(96, 96, kappa, seed=10)  # sigma_max = 5
    p = S.plan(S.SvdConfig(kappa=kappa, l0_policy="estimate_at_plan"),
               a.shape, a.dtype)
    u, s, vh = p.svd(a)
    s0 = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-10)
    assert float(C.orthogonality(u)) < 1e-13


def test_unconsumed_config_knobs_fail_loudly():
    """An explicitly-set knob the chosen backend's plan does not consume
    is a configuration error naming the method, not a silent drop."""
    with pytest.raises(ValueError, match="'qdwh' does not use r="):
        S.plan(S.SvdConfig(method="qdwh", r=4), (16, 16), jnp.float64)
    with pytest.raises(ValueError, match="does not use qr_mode="):
        C.polar_decompose(jnp.eye(16), method="qdwh", qr_mode="chol")
    with pytest.raises(ValueError, match="does not use qr_iters="):
        C.polar_decompose(jnp.eye(16), method="zolo", qr_iters=2)
    with pytest.raises(ValueError, match="does not use l0="):
        C.polar_decompose(jnp.eye(16), method="newton", l0=1e-3)
    # the dynamic Zolo bindings DO consume qr_mode — as the peeled first
    # iteration's first_mode (same knob, dynamic spelling)
    q, _, _ = C.polar_decompose(jnp.eye(16), method="zolo",
                                qr_mode="chol")
    assert float(C.orthogonality(q)) < 1e-13
    p = S.plan(S.SvdConfig(method="zolo", qr_mode="cholqr2"), (16, 16),
               jnp.float64)
    assert p._backend_kwargs["first_mode"] == "cholqr2"


def test_plan_scale_power_handles_unscaled_input():
    """scale='power' lets a static plan take an un-normalized matrix and
    still return the singular values of the original input."""
    kappa = 1e3
    a = 37.0 * make_matrix(64, 48, kappa, seed=8)  # sigma_max = 37
    p = S.plan(S.SvdConfig(method="zolo_static", l0=0.9 / kappa,
                           scale="power"), a.shape, a.dtype)
    u, s, vh = p.svd(a)
    s0 = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-10)
    q, h, _ = p.polar(a)
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert rec < 1e-12


def test_plan_validation_errors():
    cfg = S.SvdConfig(method="zolo_static", l0=1e-3)
    p = S.plan(cfg, (32, 16), jnp.float64)
    with pytest.raises(ValueError, match="shape"):
        p.svd(jnp.zeros((16, 16)))
    with pytest.raises(ValueError, match="dtype"):
        p.svd(jnp.zeros((32, 16), jnp.float32))
    with pytest.raises(ValueError, match="mesh"):
        S.plan(S.SvdConfig(mode="grouped", l0=1e-3), (32, 16),
               jnp.float64)
    with pytest.raises(ValueError, match="dynamic"):
        S.plan(S.SvdConfig(method="zolo", mode="static", l0=1e-3),
               (32, 16), jnp.float64)
    with pytest.raises(ValueError, match="l0"):
        S.plan(S.SvdConfig(method="zolo_static"), (32, 16), jnp.float64)
    with pytest.raises(ValueError, match="kappa"):
        S.plan(S.SvdConfig(l0_policy="estimate_at_plan"), (32, 16),
               jnp.float64)
    with pytest.raises(ValueError, match="runtime"):
        S.SvdConfig(l0_policy="runtime", l0=1e-3)
    with pytest.raises(ValueError, match="hashable"):
        S.SvdConfig(extra=(("x", jnp.zeros(3)),))


def test_config_is_frozen_and_replaceable():
    cfg = S.SvdConfig(method="zolo_static", l0=1e-3)
    with pytest.raises(Exception):
        cfg.method = "qdwh"
    cfg2 = cfg.replace(r=4)
    assert cfg2.r == 4 and cfg.r is None and cfg2.l0 == 1e-3
    assert hash(cfg) != hash(cfg2)
    # dict-valued extra is normalized to a sorted hashable tuple
    assert S.SvdConfig(extra={"b": 2, "a": 1}).extra == (("a", 1), ("b", 2))


def test_orthogonalize_reuses_plan_across_steps():
    """The ZoloMuon path: repeated steps at one parameter kind reuse one
    compiled executable (no per-step schedule rebuild or retrace)."""
    from repro.optim.muon import orthogonalize

    rng = np.random.default_rng(0)
    m1 = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    m2 = jnp.asarray(rng.standard_normal((64, 48)), jnp.float32)
    orthogonalize(m1)  # may compile
    t0 = S.trace_count()
    o = orthogonalize(m2)
    assert S.trace_count() == t0, "second optimizer step retraced"
    # the muon plan is pinned per parameter kind: sweeping many other
    # configs through the solver's global LRU must not evict it
    for i in range(130):
        S.plan(S.SvdConfig(method="qdwh_static", l0=1e-3 / (i + 1)),
               (8, 8), jnp.float64)
    t1 = S.trace_count()
    o = orthogonalize(m2)
    assert S.trace_count() == t1, "muon plan evicted under LRU pressure"
    u, _, vt = np.linalg.svd(np.asarray(m2, np.float64),
                             full_matrices=False)
    np.testing.assert_allclose(np.asarray(o, np.float64), u @ vt,
                               atol=2e-3)


def test_plan_zolo_pallas_matches_zolo_static():
    """The kernel-backed backend through the full plan path: cached plan
    identity, schedule binding, zero retrace, and parity with the XLA
    (zolo_static) backend at f32-accumulation tolerance."""
    kappa = 1e3
    a = make_matrix(96, 64, kappa, dtype=jnp.float32, seed=12)
    cfg = S.SvdConfig(method="zolo_pallas", l0=0.9 / kappa, r=2)
    p = S.plan(cfg, a.shape, a.dtype)
    assert p.method == "zolo_pallas" and p.mode == "static"
    assert p.schedule is not None and len(p.schedule) >= 1
    assert p is S.plan(cfg, a.shape, a.dtype)  # cached plan identity
    q, h, info = p.polar(a)
    t0 = S.trace_count()
    q2, _, _ = p.polar(a)
    assert S.trace_count() == t0, "second plan.polar call retraced"

    ref = S.plan(S.SvdConfig(method="zolo_static", l0=0.9 / kappa, r=2),
                 a.shape, a.dtype)
    q_r, h_r, _ = ref.polar(a)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_r),
                               atol=5e-5, rtol=5e-5)
    u, s, vh = p.svd(a)
    s0 = np.linalg.svd(np.asarray(a, np.float64), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-4)
    assert float(C.orthogonality(u)) < 5e-6


def test_plan_zolo_pallas_tile_knobs_via_extra():
    """Tile sizes thread from SvdConfig.extra to the kernel wrappers."""
    kappa = 1e2
    a = make_matrix(64, 48, kappa, dtype=jnp.float32, seed=13)
    p = S.plan(S.SvdConfig(method="zolo_pallas", l0=0.9 / kappa, r=2,
                           extra=(("bk", 128), ("bn", 128))),
               a.shape, a.dtype)
    q, _, _ = p.polar(a, want_h=False)
    assert float(C.orthogonality(q)) < 5e-6
    with pytest.raises(ValueError, match="alignment"):
        p_bad = S.plan(S.SvdConfig(method="zolo_pallas", l0=0.9 / kappa,
                                   r=2, extra=(("bn", 64),)),
                       a.shape, a.dtype)
        p_bad.polar(a, want_h=False)


def test_auto_scores_zolo_pallas_without_picking_baselines():
    """method='auto' must score the kernel backend via its registered
    flops_fn — on CPU the interpret-mode penalty keeps it from winning,
    and the pick is never an oracle/baseline."""
    pallas_spec = registry.get_polar("zolo_pallas")
    assert pallas_spec.flops_fn is not None
    static_spec = registry.get_polar("zolo_static")
    # off-TPU the kernel backend scores strictly worse than the XLA path
    kw = dict(r=2, kappa=1e6)
    assert pallas_spec.flops_fn(128, 96, **kw) > \
        static_spec.flops_fn(128, 96, **kw)
    p = S.plan(S.SvdConfig(kappa=1e6, l0_policy="estimate_at_plan"),
               (128, 96), jnp.float64)
    spec = registry.get_polar(p.method)
    assert not spec.is_oracle and not spec.baseline
    assert p.flops_estimate is not None
    # the kernels accumulate in f32: an f64 plan must price zolo_pallas
    # above the f32 score so auto never silently degrades precision
    # (compared inside the f32 NaN envelope, where f32 is plannable)
    kw_env = dict(r=2, kappa=1e4)
    assert pallas_spec.flops_fn(128, 96, dtype=jnp.float64, **kw_env) > \
        pallas_spec.flops_fn(128, 96, dtype=jnp.float32, **kw_env)
    # beyond the envelope an f32 pallas plan would raise in its plan_fn,
    # so the cost model prices it out of auto entirely
    assert pallas_spec.flops_fn(128, 96, dtype=jnp.float32, **kw) == \
        float("inf")


def test_flops_fn_sep_degree():
    """The grouped cost model is mesh-shape-aware: at fixed r the score
    falls as the sep degree distributes each group's Gram/solve work,
    communication keeps it above the ideal linear speedup, and the
    non-grouped score ignores sep entirely."""
    from repro.dist import grouped_iteration_flops

    spec = registry.get_polar("zolo_static")
    kw = dict(r=2, kappa=1e6, grouped=True)
    f1 = spec.flops_fn(2048, 1024, sep=1, **kw)
    f4 = spec.flops_fn(2048, 1024, sep=4, **kw)
    f8 = spec.flops_fn(2048, 1024, sep=8, **kw)
    assert f8 < f4 < f1
    assert f4 > f1 / 4  # replicated Cholesky + psum term: not linear
    # sep has no effect outside grouped execution
    assert spec.flops_fn(2048, 1024, r=2, kappa=1e6, sep=4) == \
        spec.flops_fn(2048, 1024, r=2, kappa=1e6)
    # gram-shared accounting is the single-address-space mode: a sep
    # degree is meaningless there and must fail loudly
    with pytest.raises(ValueError, match="sep"):
        grouped_iteration_flops(256, 128, 2, 5, True, sep=4)
    with pytest.raises(ValueError, match="sep"):
        grouped_iteration_flops(256, 128, 2, 5, False, sep=0)
    # sep=1 keeps the pre-activation totals (cost-model back-compat,
    # modulo the now-charged "zolo" combine psum)
    m, n, r, iters = 512, 256, 3, 5
    shared = grouped_iteration_flops(m, n, r, iters, True)
    assert shared == iters * (2*m*n*n + r * (n**3/3 + 2*m*n*n))


def test_plan_records_sep_factorization():
    """Grouped plans record the mesh's (r, sep) factorization; the
    degenerate single-device mesh is (r=1, sep=1).  (sep>1 meshes are
    exercised by the 8-device subprocess tests in test_grouped.py.)"""
    from repro.dist import zolo_group_mesh

    mesh = zolo_group_mesh(1)
    p = S.plan(S.SvdConfig(kappa=1e3, l0_policy="estimate_at_plan", r=1),
               (64, 32), jnp.float64, mesh=mesh)
    assert p.mode == "grouped" and p.r == 1 and p.sep == 1
    assert "sep" not in repr(p) or "sep=1" in repr(p)
    # non-grouped plans always record sep=1
    p2 = S.plan(S.SvdConfig(method="zolo_static", l0=1e-3), (64, 32),
                jnp.float64)
    assert p2.sep == 1 and "sep" not in repr(p2)


def test_runtime_l0_with_mesh_resolves_dynamic_grouped():
    """The adaptive path: l0_policy='runtime' + mesh= resolves to the
    runtime-conditioning grouped backend and executes on the degenerate
    single-device mesh (sep>1 meshes: subprocess tests in
    test_grouped.py)."""
    from repro.dist import zolo_group_mesh

    mesh = zolo_group_mesh(1)
    p = S.plan(S.SvdConfig(l0_policy="runtime"), (64, 32), jnp.float64,
               mesh=mesh)
    assert p.method == "zolo_grouped_dynamic" and p.mode == "grouped"
    spec = registry.get_polar(p.method)
    assert spec.dynamic and spec.supports_grouped
    assert p.schedule is None
    a = make_matrix(64, 32, 1e5, seed=21)
    q, h, info = p.polar(a)
    assert float(C.orthogonality(q)) < 1e-13
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert rec < 1e-12
    t0 = S.trace_count()
    p.polar(make_matrix(64, 32, 1e2, seed=22))  # different conditioning
    assert S.trace_count() == t0, "conditioning change retraced"


def test_dynamic_mode_reaches_pallas_backend():
    """Satellite of the engine refactor: the dynamic schedule source
    accepts the Pallas ops bundle — zolo_pallas_dynamic is plannable
    with mode='dynamic' (runtime conditioning on the kernel hot loops),
    scored but never auto-picked off-TPU."""
    a = make_matrix(96, 64, 1e3, dtype=jnp.float32, seed=23)
    p = S.plan(S.SvdConfig(method="zolo_pallas_dynamic"), a.shape,
               a.dtype)
    assert p.mode == "dynamic" and registry.get_polar(p.method).dynamic
    q, _, _ = p.polar(a, want_h=False)
    ref = S.plan(S.SvdConfig(method="zolo"), a.shape, a.dtype)
    q_r, _, _ = ref.polar(a, want_h=False)
    np.testing.assert_allclose(np.asarray(q), np.asarray(q_r),
                               atol=5e-5, rtol=5e-5)
    t0 = S.trace_count()
    p.polar(a, want_h=False)
    assert S.trace_count() == t0
    # the off-TPU interpret penalty keeps auto-dynamic off the kernels
    pd = S.plan(S.SvdConfig(l0_policy="runtime"), a.shape, jnp.float64)
    assert pd.method != "zolo_pallas_dynamic"
    spec = registry.get_polar("zolo_pallas_dynamic")
    kw = dict(r=2, kappa=1e6)
    assert spec.flops_fn(128, 96, **kw) > \
        registry.get_polar("zolo").flops_fn(128, 96, **kw)


def test_comm_flops_per_word_override():
    """SvdConfig.extra['comm_flops_per_word'] (the comm_calibrate.py
    calibration) reaches every grouped cost model — scoring and
    plan.flops_estimate — and never leaks to the backend as a kwarg."""
    from repro.dist import zolo_group_mesh

    spec = registry.get_polar("zolo_grouped")
    kw = dict(r=2, kappa=1e4, grouped=True, sep=4)
    assert spec.flops_fn(256, 128, comm_flops_per_word=500.0, **kw) > \
        spec.flops_fn(256, 128, **kw)

    mesh = zolo_group_mesh(1)
    base_cfg = S.SvdConfig(kappa=1e4, l0_policy="estimate_at_plan")
    p0 = S.plan(base_cfg, (64, 32), jnp.float64, mesh=mesh)
    p1 = S.plan(base_cfg.replace(
        extra=(("comm_flops_per_word", 1e4),)), (64, 32), jnp.float64,
        mesh=mesh)
    assert p1.method == p0.method  # calibration rescales, not re-picks,
    # on the degenerate sep=1 mesh (r=1: no live psum term at sep=1
    # means equal estimates there, so compare the sep>1 model directly)
    a = make_matrix(64, 32, 1e4, seed=24)
    q, _, _ = p1.polar(a, want_h=False)  # knob must NOT reach the driver
    assert float(C.orthogonality(q)) < 1e-13


def test_capability_errors_list_compatible_backends():
    """l0_policy='runtime' / mode='dynamic' failures name only backends
    the caller could actually switch to: grouped-capable dynamic ones
    when a mesh is bound, non-mesh dynamic ones otherwise."""
    from repro.dist import zolo_group_mesh

    mesh = zolo_group_mesh(1)
    with pytest.raises(ValueError) as ei:
        S.plan(S.SvdConfig(method="zolo_grouped", l0_policy="runtime"),
               (32, 16), jnp.float64, mesh=mesh)
    msg = str(ei.value)
    assert "zolo_grouped_dynamic" in msg
    # mesh-incompatible dynamic backends must not be suggested
    assert "'zolo'" not in msg and "qdwh" not in msg and \
        "zolo_pallas_dynamic" not in msg

    with pytest.raises(ValueError) as ei:
        S.plan(S.SvdConfig(method="zolo_static", mode="dynamic"),
               (32, 16), jnp.float64)
    msg = str(ei.value)
    # no mesh: the grouped-only backend is equally unreachable
    assert "zolo_grouped_dynamic" not in msg
    assert "'zolo'" in msg

    with pytest.raises(ValueError) as ei:
        S.plan(S.SvdConfig(method="qdwh_static", l0_policy="runtime"),
               (32, 16), jnp.float64)
    msg = str(ei.value)
    assert "zolo_grouped_dynamic" not in msg and "'zolo'" in msg


def test_wrappers_share_the_plan_path():
    """polar_svd / polar_decompose resolve through the same plan cache:
    a repeated wrapper call must not re-resolve into a new plan."""
    kappa = 1e3
    a = make_matrix(48, 32, kappa, seed=9)
    C.polar_svd(a, method="zolo_static", l0=0.9 / kappa, r=2)
    stats0 = S.plan_cache_stats()
    C.polar_svd(a, method="zolo_static", l0=0.9 / kappa, r=2)
    stats1 = S.plan_cache_stats()
    assert stats1["plans"] == stats0["plans"]
    assert stats1["plan_hits"] == stats0["plan_hits"] + 1
    # a direct plan() with the same knobs shares the wrapper's plan
    # (wrappers pin scale='none': their callers pre-scale)
    S.plan(S.SvdConfig(method="zolo_static", l0=0.9 / kappa, r=2,
                       scale="none"), a.shape, a.dtype)
    assert S.plan_cache_stats()["plans"] == stats1["plans"]


def test_cache_stats_public_surface():
    """cache_stats()/pin()/set_plan_cache_capacity(): the serving
    observability hooks, with plan_cache_stats() staying back-compat."""
    base = S.cache_stats()
    assert set(base) >= {"hits", "misses", "evictions", "size",
                         "pinned", "capacity"}
    p = S.plan(S.SvdConfig(method="zolo_static", l0=1e-3, r=2),
               (40, 24), jnp.float64)
    S.plan(S.SvdConfig(method="zolo_static", l0=1e-3, r=2),
           (40, 24), jnp.float64)
    got = S.cache_stats()
    assert got["hits"] == base["hits"] + 1
    assert got["misses"] >= base["misses"] + 1
    # the legacy keys survive for existing callers
    legacy = S.plan_cache_stats()
    assert {"plans", "plan_hits", "plan_misses", "traces"} <= set(legacy)

    S.pin(p)
    assert S.cache_stats()["pinned"] >= 1
    prev = S.set_plan_cache_capacity(1)
    try:
        for kappa in (2e3, 3e3, 4e3):
            S.plan(S.SvdConfig(method="zolo_static", l0=0.9 / kappa),
                   (40, 24), jnp.float64)
        churned = S.cache_stats()
        assert churned["evictions"] > got["evictions"]
        # the pinned plan survived the squeeze: same object comes back
        again = S.plan(S.SvdConfig(method="zolo_static", l0=1e-3, r=2),
                       (40, 24), jnp.float64)
        assert again is p
        S.unpin(p)
        S.plan(S.SvdConfig(method="zolo_static", l0=0.9 / 5e3),
               (40, 24), jnp.float64)
        # unpinned, over capacity: now evictable
        assert S.cache_stats()["size"] <= 2
    finally:
        S.set_plan_cache_capacity(prev)
    with pytest.raises(ValueError, match="capacity"):
        S.set_plan_cache_capacity(0)


def test_pallas_f32_envelope_fails_loudly():
    """ROADMAP item 4a (fail-loud half): a Pallas backend planned in
    sub-f64 precision beyond the recorded NaN envelope raises at plan
    time instead of returning NaN at run time."""
    from repro.core.svd import PALLAS_F32_KAPPA_MAX

    bad = S.SvdConfig(method="zolo_pallas", kappa=1e5,
                      l0_policy="estimate_at_plan")
    with pytest.raises(ValueError, match="NaN envelope"):
        S.plan(bad, (96, 64), jnp.float32)
    with pytest.raises(ValueError, match="NaN envelope"):
        S.plan(S.SvdConfig(method="zolo_pallas_dynamic", kappa=1e5,
                           l0_policy="estimate_at_plan"),
               (96, 64), jnp.float32)
    # f64 accumulates past the envelope: allowed
    S.plan(bad, (96, 64), jnp.float64)
    # inside the envelope: allowed (the committed pd_compare setting)
    S.plan(S.SvdConfig(method="zolo_pallas", kappa=9.06e3 / 0.9,
                       l0_policy="estimate_at_plan"),
           (96, 64), jnp.float32)
    # a dynamic plan with no conditioning hint only knows kappa at run
    # time — plannable (the envelope is the caller's responsibility)
    S.plan(S.SvdConfig(method="zolo_pallas_dynamic"),
           (96, 64), jnp.float32)
    # auto never selects a backend that would raise: the envelope is
    # priced to infinity in the Pallas cost models
    p = S.plan(S.SvdConfig(kappa=10 * PALLAS_F32_KAPPA_MAX,
                           l0_policy="estimate_at_plan"),
               (96, 64), jnp.float32)
    assert "pallas" not in p.method
