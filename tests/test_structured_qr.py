"""Structured QR (paper §3.1) vs the dense stacked oracle."""

import numpy as np
import pytest
from _propcheck import given, settings, st

import jax.numpy as jnp
import repro.core.structured_qr  # noqa: F401  (module import kept explicit)
import sys

SQ = sys.modules["repro.core.structured_qr"]

from conftest import make_matrix


@pytest.mark.parametrize("m,n,blk", [(64, 32, 8), (100, 60, 16),
                                     (128, 96, 32), (90, 50, 32),
                                     (200, 200, 32)])
def test_matches_dense_oracle(m, n, blk):
    x = make_matrix(m, n, 50.0, seed=m + n)
    sqc = jnp.float64(0.37)
    q1, q2 = SQ.structured_qr_q1q2(x, sqc, block=blk)
    q1d, q2d = SQ.dense_stacked_qr_q1q2(x, sqc)
    assert float(jnp.abs(q1 @ q2.T - q1d @ q2d.T).max()) < 1e-12
    orth = jnp.linalg.norm(q1.T @ q1 + q2.T @ q2 - jnp.eye(n))
    assert float(orth) < 1e-12


def test_reconstruction():
    m, n, blk = 128, 64, 32
    x = make_matrix(m, n, 100.0, seed=5)
    sqc = jnp.float64(0.61)
    r, v_all, t_all = SQ.structured_qr_factor(x, sqc, block=blk)
    q1, q2 = SQ.apply_q_structured(v_all, t_all, m, block=blk)
    assert float(jnp.linalg.norm(q1 @ r - x)) < 1e-12
    assert float(jnp.linalg.norm(q2 @ r - sqc * jnp.eye(n))) < 1e-12
    # R upper triangular
    assert float(jnp.abs(jnp.tril(r, -1)).max()) == 0.0


def test_rowwise_stability_at_tiny_shift():
    """The property that makes Zolo-PD backward stable (DESIGN.md §3):
    at shift sqrt(c) ~ 1e-9 on an ill-conditioned X, the identity block's
    backward error must stay absolute-eps *and* the Q1 Q2^T product must
    match the (row-sorted, LAPACK) dense factorization."""
    m, n = 128, 64
    x = make_matrix(m, n, 1e11, seed=7)
    sqc = jnp.float64(9.6e-10)
    r, v_all, t_all = SQ.structured_qr_factor(x, sqc, block=32)
    q1, q2 = SQ.apply_q_structured(v_all, t_all, m, block=32)
    assert float(jnp.linalg.norm(q2 @ r - sqc * jnp.eye(n))) < 1e-14
    assert float(jnp.linalg.norm(q1 @ r - x)) < 1e-13
    orth = jnp.linalg.norm(q1.T @ q1 + q2.T @ q2 - jnp.eye(n))
    assert float(orth) < 1e-12


@given(st.integers(min_value=2, max_value=6),
       st.integers(min_value=1, max_value=5),
       st.floats(min_value=1e-6, max_value=10.0))
@settings(max_examples=8, deadline=None)
def test_property_random_shapes(mb, nb, c):
    m, n = 16 * mb + 16, 16 * nb  # m > n guaranteed
    x = make_matrix(m, n, 10.0, seed=mb * 7 + nb)
    q1, q2 = SQ.structured_qr_q1q2(x, jnp.float64(np.sqrt(c)), block=16)
    q1d, q2d = SQ.dense_stacked_qr_q1q2(x, jnp.float64(np.sqrt(c)))
    assert float(jnp.abs(q1 @ q2.T - q1d @ q2d.T).max()) < 1e-11


def test_flop_model_shows_savings():
    f = SQ.structured_qr_flops(10_000, 5_000, 64)
    # paper Table 2 reports 1.18-1.51x; the analytic model should sit there
    assert 1.1 < f["speedup_geqrf"] < 2.0
    assert 1.1 < f["speedup_orgqr"] < 2.0
