"""Deterministic fallback for the `hypothesis` API subset used here.

Tier-1 must run on a bare install (jax + numpy + scipy + pytest).  When
hypothesis is available (``pip install -e ".[test]"``) the real library
is re-exported unchanged; otherwise ``@given`` runs ``max_examples``
samples drawn from the declared strategies with a seed derived from the
test name — deterministic across runs and machines, boundary values
first so the extremes are always exercised.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import math
    import zlib

    import numpy as np

    class _Strategy:
        def __init__(self, draw, boundary):
            self._draw = draw
            self.boundary = boundary  # deterministic edge values, tried first

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def floats(min_value, max_value, **_):
            lo, hi = float(min_value), float(max_value)
            log_uniform = lo > 0 and hi / lo > 1e3

            def draw(rng):
                if log_uniform:  # span decades the way hypothesis shrinks
                    return float(math.exp(
                        rng.uniform(math.log(lo), math.log(hi))))
                return float(rng.uniform(lo, hi))

            return _Strategy(draw, (lo, hi))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                (int(min_value), int(max_value)))

    st = _Strategies()

    def settings(max_examples: int = 10, **_):
        # works in either stacking order with @given: the attribute is
        # read at call time, whether set on the raw test fn (settings
        # innermost) or on the runner (settings outermost)
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strats):
        def deco(fn):
            def runner():
                n = getattr(runner, "_max_examples",
                            getattr(fn, "_max_examples", 10))
                seed = zlib.crc32(fn.__name__.encode())
                rng = np.random.default_rng(seed)
                for i in range(n):
                    if i < 2:  # all-min, then all-max boundary cases
                        vals = [s.boundary[i] for s in strats]
                    else:
                        vals = [s.draw(rng) for s in strats]
                    try:
                        fn(*vals)
                    except Exception:
                        print(f"{fn.__name__}: falsified with {vals!r}")
                        raise

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
