"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret mode)."""

import numpy as np
import pytest

import jax.numpy as jnp
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,n", [(256, 256), (300, 130), (512, 384),
                                 (128, 640), (64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel(m, n, dtype, rng):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype)
    c = 0.73
    got = ops.gram(a, c)
    want = ref.gram_ref(a, c)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("m,k,n", [(256, 512, 256), (130, 70, 200),
                                   (64, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel(m, k, n, dtype, rng):
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    got = ops.matmul(a, b, alpha=1.5)
    want = ref.matmul_ref(a, b, alpha=1.5)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol * np.sqrt(k), rtol=tol)


@pytest.mark.parametrize("r", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_polar_update_kernel(r, dtype, rng):
    x = jnp.asarray(rng.standard_normal((160, 200)), dtype)
    t = jnp.asarray(rng.standard_normal((r, 160, 200)), dtype)
    a = jnp.asarray(rng.standard_normal(r), jnp.float32)
    got = ops.polar_update(x, t, a, 0.987)
    want = ref.polar_update_ref(x, t, a, 0.987)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol * r, rtol=tol * r)


def test_gram_kernel_in_zolo_context(rng):
    """Kernel output is good enough to drive a full Zolo iteration."""
    import repro.core as C
    a = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)
    a = a / C.sigma_max_upper(a)
    g_kernel = ops.gram(a, 1e-3)
    g_ref = ref.gram_ref(a, 1e-3)
    l_k = jnp.linalg.cholesky(g_kernel)
    l_r = jnp.linalg.cholesky(g_ref)
    assert bool(jnp.all(jnp.isfinite(l_k)))
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r), atol=1e-3)


@pytest.mark.parametrize("s,bq,bk", [(128, 64, 64), (256, 128, 64),
                                     (192, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(s, bq, bk, dtype, rng):
    from repro.kernels.flash_attention import flash_attention_kernel_call
    b, h, d = 2, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    got = ops.flash_attention(q, k, v, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_matches_model_attention(rng):
    """Kernel vs the pure-JAX chunked flash used by the model stack."""
    from repro.models.attention import flash_attention as model_flash
    b, s, kv, g, d = 1, 128, 2, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, kv, g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    pos = jnp.arange(s)
    want = model_flash(q, k, v, pos, pos, q_chunk=64, kv_chunk=64)
    # expand GQA and run the kernel
    qe = q.reshape(b, s, kv * g, d)
    ke = jnp.repeat(k, g, axis=2)
    ve = jnp.repeat(v, g, axis=2)
    got = ops.flash_attention(qe, ke, ve, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.reshape(b, s, kv * g, d)),
                               atol=5e-5, rtol=5e-5)
