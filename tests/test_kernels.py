"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret mode)."""

import numpy as np
import pytest

import jax.numpy as jnp
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,n", [(256, 256), (300, 130), (512, 384),
                                 (128, 640), (64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel(m, n, dtype, rng):
    a = jnp.asarray(rng.standard_normal((m, n)), dtype)
    c = 0.73
    got = ops.gram(a, c)
    want = ref.gram_ref(a, c)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("m,k,n", [(256, 512, 256), (130, 70, 200),
                                   (64, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel(m, k, n, dtype, rng):
    a = jnp.asarray(rng.standard_normal((m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    got = ops.matmul(a, b, alpha=1.5)
    want = ref.matmul_ref(a, b, alpha=1.5)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol * np.sqrt(k), rtol=tol)


@pytest.mark.parametrize("r", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_polar_update_kernel(r, dtype, rng):
    x = jnp.asarray(rng.standard_normal((160, 200)), dtype)
    t = jnp.asarray(rng.standard_normal((r, 160, 200)), dtype)
    a = jnp.asarray(rng.standard_normal(r), jnp.float32)
    got = ops.polar_update(x, t, a, 0.987)
    want = ref.polar_update_ref(x, t, a, 0.987)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol * r, rtol=tol * r)


# Non-128-multiple shapes: the _pad_to/_pick_tile + slice-back round trip
# must match the oracle exactly where the data lives (padding rows/cols
# are sliced off).  (130, 70) pads both dims below one tile; (257, 129)
# pads both dims one past a tile boundary.


@pytest.mark.parametrize("m,n", [(130, 70), (257, 129)])
def test_gram_kernel_padding_roundtrip(m, n, rng):
    a = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    got = ops.gram(a, 0.31)
    want = ref.gram_ref(a, 0.31)
    assert got.shape == (n, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("m,k,n", [(130, 70, 257), (257, 129, 70)])
def test_matmul_kernel_padding_roundtrip(m, k, n, rng):
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    got = ops.matmul(a, b, alpha=0.7)
    want = ref.matmul_ref(a, b, alpha=0.7)
    assert got.shape == (m, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("m,n", [(130, 70), (257, 129)])
def test_polar_update_kernel_padding_roundtrip(m, n, rng):
    r = 3
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((r, m, n)), jnp.float32)
    a = jnp.asarray(rng.standard_normal(r), jnp.float32)
    got = ops.polar_update(x, t, a, 0.93)
    want = ref.polar_update_ref(x, t, a, 0.93)
    assert got.shape == (m, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("m,n", [(130, 70), (257, 129)])
def test_grouped_combine_kernel_padding_roundtrip(m, n, rng):
    """The grouped-combine kernel (fused pre-psum contribution) through
    the pad/slice wrapper at non-tile-multiple shapes, for both the
    X-carrying (xw=1) and term-only (xw=0) group roles."""
    r = 2
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((r, m, n)), jnp.float32)
    a = jnp.asarray(rng.standard_normal(r), jnp.float32)
    for xw in (1.0, 0.0):
        got = ops.grouped_combine(x, t, a, 0.93, xw, use_pallas=True)
        want = ref.grouped_combine_ref(x, t, a, 0.93, xw)
        assert got.shape == (m, n)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)


def test_grouped_combine_psum_identity(rng):
    """Summing per-group contributions with a one-hot xw reproduces the
    unfused combine mhat * (x + sum_j a_j t_j) — the invariant that lets
    the "zolo" psum carry the next iterate directly."""
    m, n = 96, 64
    x = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((2, m, n)), jnp.float32)
    a = jnp.asarray([0.7, -1.3], jnp.float32)
    mhat = 0.87
    y0 = ops.grouped_combine(x, t[:1], a[:1], mhat, 1.0, use_pallas=True)
    y1 = ops.grouped_combine(x, t[1:], a[1:], mhat, 0.0, use_pallas=True)
    want = ref.polar_update_ref(x, t, a, mhat)
    np.testing.assert_allclose(np.asarray(y0 + y1), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_grouped_combine_ref_keeps_f64(rng):
    """Off-TPU the oracle IS the grouped driver's combine: f64 inputs
    must accumulate in f64 (a hard f32 cast would sink the distributed
    parity tolerances)."""
    x = jnp.asarray(rng.standard_normal((16, 8)), jnp.float64)
    t = jnp.asarray(rng.standard_normal((1, 16, 8)), jnp.float64)
    a = jnp.asarray([0.731], jnp.float64)
    got = ref.grouped_combine_ref(x, t, a, 0.917, 1.0)
    assert got.dtype == jnp.float64
    want = 0.917 * (np.asarray(x) + 0.731 * np.asarray(t[0]))
    # 1e-15: only f64 accumulation passes (an f32 cast errs at ~1e-8)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-15, rtol=0)


def test_pick_tile_non_multiple_target_terminates():
    """A tile target that is not a 128 multiple must round down to an
    aligned divisor of the padded dim (the old decrement loop walked
    past zero and never terminated)."""
    from repro.kernels.ops import _pick_tile
    assert _pick_tile(130, 200) == 128
    assert _pick_tile(512, 300) == 256
    assert _pick_tile(70, 512) == 128
    t = _pick_tile(257, 300)
    assert t % 128 == 0 and (257 + (-257) % 128) % t == 0
    with pytest.raises(ValueError, match="alignment"):
        _pick_tile(256, 64)


def test_zolo_pallas_backend_matches_zolo(rng):
    """The registered kernel-backed polar backend vs the dynamic XLA
    path on a scaled random matrix (interpret mode on CPU)."""
    import repro.core as C
    kappa = 1e3
    from conftest import make_matrix
    a = make_matrix(96, 64, kappa, dtype=jnp.float32, seed=5)
    q_k, h_k, _ = C.polar_decompose(a, method="zolo_pallas",
                                    l0=0.9 / kappa, r=2, want_h=True)
    q_x, h_x, _ = C.polar_decompose(a, method="zolo", alpha=1.0,
                                    l=0.9 / kappa, r=2)
    np.testing.assert_allclose(np.asarray(q_k), np.asarray(q_x),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_x),
                               atol=5e-5, rtol=5e-5)
    assert float(C.orthogonality(q_k)) < 5e-6


def test_zolo_pallas_ops_bundle_matches_default(rng):
    """pallas_zolo_ops vs DEFAULT_OPS on one full static driver run,
    including a non-128-multiple shape (padding inside the iteration)."""
    import repro.core as C
    kappa = 1e2
    from conftest import make_matrix
    a = make_matrix(130, 70, kappa, dtype=jnp.float32, seed=6)
    q_d, _, _ = C.zolo_pd_static(a, l0=0.9 / kappa, r=2)
    q_p, _, _ = C.zolo_pd_static(a, l0=0.9 / kappa, r=2,
                                 ops=C.pallas_zolo_ops(bn=128, bk=128,
                                                       bm=128))
    np.testing.assert_allclose(np.asarray(q_p), np.asarray(q_d),
                               atol=5e-5, rtol=5e-5)


def test_gram_kernel_in_zolo_context(rng):
    """Kernel output is good enough to drive a full Zolo iteration."""
    import repro.core as C
    a = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)
    a = a / C.sigma_max_upper(a)
    g_kernel = ops.gram(a, 1e-3)
    g_ref = ref.gram_ref(a, 1e-3)
    l_k = jnp.linalg.cholesky(g_kernel)
    l_r = jnp.linalg.cholesky(g_ref)
    assert bool(jnp.all(jnp.isfinite(l_k)))
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_r), atol=1e-3)


@pytest.mark.parametrize("s,bq,bk", [(128, 64, 64), (256, 128, 64),
                                     (192, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(s, bq, bk, dtype, rng):
    from repro.kernels.flash_attention import flash_attention_kernel_call
    b, h, d = 2, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    got = ops.flash_attention(q, k, v, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_matches_model_attention(rng):
    """Kernel vs the pure-JAX chunked flash used by the model stack."""
    from repro.models.attention import flash_attention as model_flash
    b, s, kv, g, d = 1, 128, 2, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, kv, g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    pos = jnp.arange(s)
    want = model_flash(q, k, v, pos, pos, q_chunk=64, kv_chunk=64)
    # expand GQA and run the kernel
    qe = q.reshape(b, s, kv * g, d)
    ke = jnp.repeat(k, g, axis=2)
    ve = jnp.repeat(v, g, axis=2)
    got = ops.flash_attention(qe, ke, ve, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.reshape(b, s, kv * g, d)),
                               atol=5e-5, rtol=5e-5)
