"""The bf16 kernel envelope (ROADMAP item 4): shift clamp, envelope
table, planner gating, and the runtime health judge.

Four contracts, one per section:

* the kernel-side shift clamp keeps the f32 Pallas path finite and
  orth-clean in the former NaN regime (kappa 2e4-3e4 and beyond);
* bf16-input kernels return finite, orth-clean factors up to the
  recorded ``("bfloat16", "float32")`` envelope entry;
* ``method="auto"`` (and explicit plans) never run a Pallas backend
  outside its compute dtype's envelope — priced to infinity in scoring,
  ValueError in plan_fn;
* ``judge_plan`` fires exactly at the envelope breach for bf16 compute
  plans, through the same registry table.
"""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core as C
import repro.solver as S
from repro.core import registry
from repro.core import zolo as Z
from repro.core.svd import (PALLAS_BF16_KAPPA_MAX, PALLAS_F32_KAPPA_MAX,
                            PALLAS_KAPPA_ENVELOPE, _zolo_pallas_flops)
from repro.core.zolo_pallas import zolo_pd_pallas
from repro.kernels import ops, ref
from repro.resilience import health as H

from conftest import make_matrix


# --- shift clamp (ROADMAP 4a): the f32 indefinite-Gram fix ------------------


def test_gram_kernel_clamps_tiny_positive_shift():
    """A positive shift below the eps(f32)-relative floor is ridged up
    in-kernel: the returned diagonal carries the floor, not the raw c
    (which f32 addition would round away against a large diagonal)."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((256, 128)) * 30.0, jnp.float32)
    g0 = ref.gram_ref(a, 0.0)
    diag_max = float(jnp.max(jnp.diagonal(g0)))
    floor = 8.0 * float(jnp.finfo(jnp.float32).eps) * diag_max
    c_tiny = floor / 100.0

    g = ops.gram(a, c_tiny)
    applied = float(jnp.max(jnp.diagonal(g) - jnp.diagonal(g0)))
    # the effective shift is the floor (within f32 rounding), not c_tiny
    assert applied > 10.0 * c_tiny
    assert applied == pytest.approx(floor, rel=0.3)

    # a shift already above the floor passes through unclamped
    c_big = 10.0 * floor
    g_big = ops.gram(a, c_big)
    applied_big = float(jnp.max(jnp.diagonal(g_big) - jnp.diagonal(g0)))
    assert applied_big == pytest.approx(c_big, rel=0.1)

    # c == 0 is never touched: unshifted Grams (g2, sigma_min estimates)
    # stay exact
    np.testing.assert_allclose(np.asarray(ops.gram(a, 0.0)),
                               np.asarray(g0), rtol=1e-5)


def test_engine_clamp_leaves_f64_shifts_alone():
    """f64 iterates never clamp: Zolotarev shifts ~1e-20 at kappa 1e10
    are real and must reach the factorization unmodified."""
    g = jnp.eye(8, dtype=jnp.float64) * 3.0
    c = jnp.asarray([1e-20], jnp.float64)
    out = Z._clamp_shift(c, g, jnp.float64)
    assert float(out[0]) == 1e-20


@pytest.mark.parametrize("kappa", [2.0e4, 3.0e4])
def test_f32_pallas_static_finite_in_former_nan_regime(kappa):
    """Before the clamp, kappa >= 3e4 sent the f32 shifted Gram
    indefinite and Cholesky returned NaN (the measured ROADMAP 4a edge,
    with 2e4 the last clean decade).  With the in-kernel ridge the same
    path stays finite and orthogonal through and past the old edge."""
    n = 128
    a = make_matrix(2 * n, n, kappa, dtype=jnp.float32, seed=5)
    q, _, info = zolo_pd_pallas(a, l0=0.9 / kappa)
    assert bool(jnp.all(jnp.isfinite(q)))
    assert float(C.orthogonality(q)) < 1e-5


# --- the bf16 envelope: accuracy inside, recorded table ----------------------


def test_envelope_table_entries():
    assert PALLAS_KAPPA_ENVELOPE[("float32", "float32")] \
        == PALLAS_F32_KAPPA_MAX
    assert PALLAS_KAPPA_ENVELOPE[("bfloat16", "float32")] \
        == PALLAS_BF16_KAPPA_MAX
    # fail-closed consistency: no sub-f32 entry may exceed the f32 cap
    assert PALLAS_BF16_KAPPA_MAX <= PALLAS_F32_KAPPA_MAX
    for spec_name in ("zolo_pallas", "zolo_pallas_dynamic"):
        spec = registry.get_polar(spec_name)
        assert spec.kappa_envelope == PALLAS_KAPPA_ENVELOPE


def test_envelope_resolution_per_dtype():
    spec = registry.get_polar("zolo_pallas")
    assert registry.envelope_kappa_max(spec, jnp.dtype(jnp.float64)) is None
    assert registry.envelope_kappa_max(spec, jnp.dtype(jnp.float32)) \
        == PALLAS_F32_KAPPA_MAX
    assert registry.envelope_kappa_max(spec, jnp.dtype(jnp.bfloat16)) \
        == PALLAS_BF16_KAPPA_MAX
    # an unmeasured narrow dtype fails closed to the table minimum
    assert registry.envelope_kappa_max(spec, jnp.dtype(jnp.float16)) \
        == min(PALLAS_KAPPA_ENVELOPE.values())


@pytest.mark.parametrize("kappa", [1.0e2, 1.0e3, PALLAS_BF16_KAPPA_MAX])
def test_bf16_kernels_accurate_inside_envelope(kappa):
    """bf16-input kernels (f32 accumulation + shift clamp) return
    finite, orth-clean factors through the recorded envelope cap."""
    n = 128
    a32 = make_matrix(2 * n, n, kappa, dtype=jnp.float32, seed=7)
    a = a32.astype(jnp.bfloat16)
    q, _, info = zolo_pd_pallas(a, l0=0.9 / kappa)
    assert q.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(q.astype(jnp.float32))))
    orth = float(C.orthogonality(q.astype(jnp.float32)))
    assert orth < H.default_orth_tol(jnp.bfloat16)
    # healthy bf16 solves measure orth ~ a few eps(bf16), far inside
    # the acceptance threshold — catch silent degradation early
    assert orth < 1.0e-2


def test_bf16_compute_plan_end_to_end_inside_envelope():
    """An SvdPlan with compute_dtype='bfloat16' over f32 inputs solves
    through the Pallas backend and passes its own health judgment."""
    kappa = 1.0e3
    n = 96
    a = make_matrix(2 * n, n, kappa, dtype=jnp.float32, seed=11)
    p = S.plan(S.SvdConfig(method="zolo_pallas", kappa=kappa,
                           l0_policy="estimate_at_plan",
                           compute_dtype="bfloat16"),
               a.shape, a.dtype)
    u, s, vh, health = p.svd_verified(a)
    assert u.dtype == jnp.float32  # results come back in the plan dtype
    verdict = H.judge_plan(p, health)
    assert verdict.ok, verdict.reasons
    s0 = np.linalg.svd(np.asarray(a, np.float64), compute_uv=False)
    # bf16 compute: top singular values to ~eps(bf16) relative accuracy
    np.testing.assert_allclose(np.asarray(s)[: n // 2], s0[: n // 2],
                               rtol=5e-2)


# --- planner gating: never outside the envelope ------------------------------


def test_bf16_plan_raises_beyond_bf16_cap_inside_f32_cap():
    """The per-dtype table, not the flat f32 cap, gates plan_fn: a
    kappa between the bf16 and f32 caps plans at f32 but raises at
    bf16 compute."""
    kappa = 1.5e4
    assert PALLAS_BF16_KAPPA_MAX < kappa < PALLAS_F32_KAPPA_MAX
    cfg = dict(method="zolo_pallas", kappa=kappa,
               l0_policy="estimate_at_plan")
    p32 = S.plan(S.SvdConfig(**cfg), (128, 128), jnp.float32)
    assert p32.method == "zolo_pallas"
    with pytest.raises(ValueError, match="NaN envelope"):
        S.plan(S.SvdConfig(compute_dtype="bfloat16", **cfg),
               (128, 128), jnp.float32)


def test_auto_never_selects_bf16_pallas_outside_envelope(monkeypatch):
    """Acceptance: method='auto' must not pick a Pallas backend whose
    compute dtype sits beyond its recorded envelope — even on TPU,
    where the kernels otherwise win on the fused-pass + bf16-rate
    discounts.  Simulated by faking the backend so the scoring branch
    under test (the TPU discounts) is the one that runs."""
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    S.clear_plan_cache()
    inside = 0.9 * PALLAS_BF16_KAPPA_MAX
    between = 1.5e4  # beyond bf16's cap, inside f32's

    # scoring: infinity outside the envelope, discounted inside
    flops_kw = dict(r=2, grouped=False)
    assert math.isinf(_zolo_pallas_flops(256, 128, kappa=between,
                                         dtype=jnp.dtype(jnp.bfloat16),
                                         **flops_kw))
    assert math.isfinite(_zolo_pallas_flops(256, 128, kappa=between,
                                            dtype=jnp.dtype(jnp.float32),
                                            **flops_kw))
    assert math.isfinite(_zolo_pallas_flops(256, 128, kappa=inside,
                                            dtype=jnp.dtype(jnp.bfloat16),
                                            **flops_kw))

    # end-to-end resolution: inside the envelope auto takes the kernel
    # path, outside it falls back to a non-Pallas backend (never an
    # error, never a Pallas pick)
    p_in = S.plan(S.SvdConfig(kappa=inside, l0_policy="estimate_at_plan",
                              compute_dtype="bfloat16"),
                  (256, 128), jnp.float32)
    assert p_in.method == "zolo_pallas"
    p_out = S.plan(S.SvdConfig(kappa=between,
                               l0_policy="estimate_at_plan",
                               compute_dtype="bfloat16"),
                   (256, 128), jnp.float32)
    assert "pallas" not in p_out.method
    # the same kappa at f32 compute is still inside f32's envelope
    p_f32 = S.plan(S.SvdConfig(kappa=between,
                               l0_policy="estimate_at_plan"),
                   (256, 128), jnp.float32)
    assert p_f32.method == "zolo_pallas"
    S.clear_plan_cache()


# --- runtime health: judge_plan fires exactly at the breach ------------------


def _health(kappa_est, orth=1e-4):
    return H.SolveHealth(finite=jnp.asarray(True),
                         orth=jnp.asarray(orth, jnp.float32),
                         converged=jnp.asarray(True),
                         kappa_est=jnp.asarray(kappa_est, jnp.float32))


def test_judge_plan_bf16_envelope_breach_exact():
    """A dynamic bf16 compute plan has no plan-time kappa, so the
    runtime estimate is the only envelope gate: at the cap the verdict
    holds, just beyond it the envelope reason fires."""
    p = S.plan(S.SvdConfig(method="zolo_pallas_dynamic",
                           compute_dtype="bfloat16"),
               (128, 128), jnp.float32)
    at_cap = H.judge_plan(p, _health(PALLAS_BF16_KAPPA_MAX))
    assert at_cap.ok, at_cap.reasons
    assert at_cap.kappa_max == PALLAS_BF16_KAPPA_MAX
    beyond = H.judge_plan(p, _health(1.02 * PALLAS_BF16_KAPPA_MAX))
    assert not beyond.ok
    assert any("envelope" in r for r in beyond.reasons)
    # the same runtime estimate under f32 compute is inside f32's cap
    p32 = S.plan(S.SvdConfig(method="zolo_pallas_dynamic"),
                 (128, 128), jnp.float32)
    v32 = H.judge_plan(p32, _health(1.02 * PALLAS_BF16_KAPPA_MAX))
    assert v32.ok, v32.reasons


def test_bf16_orth_tol_splits_healthy_from_broken():
    tol = H.default_orth_tol(jnp.bfloat16)
    # healthy bf16 solves measure a few eps(bf16); broken ones O(1)
    assert 1e-2 < tol < 0.5
