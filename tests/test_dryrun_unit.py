"""Dry-run machinery unit tests (parser + small-mesh lowering)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %p0), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %p1), to_apply=%add
  %rs = f32[16]{0} reduce-scatter(f32[256]{0} %p2), dimensions={0}
  ROOT %cp = u32[8]{0} collective-permute(u32[8]{0} %p3)
  %dead = f32[9] add(f32[9] %a, f32[9] %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 4 * 128 * 2
    assert out["all-reduce"]["bytes"] == 256 * 4
    assert out["reduce-scatter"]["bytes"] == 256 * 4
    assert out["collective-permute"]["bytes"] == 8 * 4
    assert out["total_bytes"] == (4 * 128 * 2 + 256 * 4 + 256 * 4 + 8 * 4)


@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_small_mesh_lowering(kind):
    """Lower all three step kinds for a reduced config on a 1x1 mesh —
    exercises the exact dry-run code path without 512 devices."""
    from repro import configs as CFG
    from repro.dist.sharding import arch_rules, tree_shardings
    from repro.launch.mesh import make_debug_mesh
    from repro.models import model as M
    from repro.models.config import ShapeConfig
    from repro.optim.muon import MuonConfig
    from repro.train.step import make_train_step, state_axes_for_params
    from repro.launch.dryrun import _sds_tree

    cfg = CFG.get_smoke_config("recurrentgemma-2b")
    shape = ShapeConfig("smoke", kind, 64, 2)
    mesh = make_debug_mesh(1, 1)
    rules = arch_rules(cfg, mesh, shape)

    if kind == "train":
        init_fn, step = make_train_step(cfg, MuonConfig())
        abstract = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
        sds = _sds_tree(abstract, tree_shardings(
            mesh, rules, state_axes_for_params(cfg, abstract.params)))
        batch = CFG.input_specs(cfg, shape, abstract=True)
        b_sds = _sds_tree(batch, tree_shardings(
            mesh, rules, {"tokens": ("batch", None)}))
        with mesh:
            compiled = jax.jit(step).lower(sds, b_sds).compile()
    elif kind == "prefill":
        abstract = jax.eval_shape(lambda k: M.init_params(cfg, k),
                                  jax.random.PRNGKey(0))
        sds = _sds_tree(abstract, tree_shardings(mesh, rules,
                                                 M.params_axes(cfg)))
        batch = CFG.input_specs(cfg, shape, abstract=True)
        b_sds = _sds_tree(batch, tree_shardings(
            mesh, rules, {"tokens": ("batch", None)}))

        def prefill_step(p, b):
            return M.prefill(p, b, cfg, max_len=shape.seq_len)

        with mesh:
            compiled = jax.jit(prefill_step).lower(sds, b_sds).compile()
    else:
        abstract = jax.eval_shape(lambda k: M.init_params(cfg, k),
                                  jax.random.PRNGKey(0))
        sds = _sds_tree(abstract, tree_shardings(mesh, rules,
                                                 M.params_axes(cfg)))
        caches = jax.eval_shape(
            lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len))
        c_sds = _sds_tree(caches, tree_shardings(mesh, rules,
                                                 M.caches_axes(cfg)))
        toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

        def serve_step(p, t, c):
            return M.decode_step(p, t, c, cfg)

        with mesh:
            compiled = jax.jit(serve_step).lower(sds, toks, c_sds).compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    assert float(cost.get("flops", 0)) > 0


def test_cell_skip_logic():
    from repro import configs as CFG
    from repro.models.config import SHAPES
    assert CFG.registry.cell_supported(
        CFG.get_config("yi-34b"), SHAPES["long_500k"]) is not None
    assert CFG.registry.cell_supported(
        CFG.get_config("mamba2-130m"), SHAPES["long_500k"]) is None
    assert CFG.registry.cell_supported(
        CFG.get_config("h2o-danube-3-4b"), SHAPES["long_500k"]) is None


def test_data_pipeline_determinism():
    from repro.data.pipeline import SyntheticLM
    d1 = SyntheticLM(1000, 32, 4, seed=3)
    d2 = SyntheticLM(1000, 32, 4, seed=3)
    np.testing.assert_array_equal(np.asarray(d1.batch_at(17)["tokens"]),
                                  np.asarray(d2.batch_at(17)["tokens"]))
    assert not np.array_equal(np.asarray(d1.batch_at(17)["tokens"]),
                              np.asarray(d1.batch_at(18)["tokens"]))
