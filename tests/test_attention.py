"""Flash attention vs naive reference; SWA; decode ring buffer."""

import numpy as np
import pytest

import jax.numpy as jnp
from repro.kernels.ref import flash_attention_ref
from repro.models import attention as A


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("sq,kv,g,window", [
    (64, 2, 2, None), (96, 1, 4, None), (64, 2, 2, 32), (128, 4, 1, 48)])
def test_flash_vs_reference(sq, kv, g, window, rng):
    b, hd = 2, 16
    q = _rand(rng, b, sq, kv, g, hd)
    k = _rand(rng, b, sq, kv, hd)
    v = _rand(rng, b, sq, kv, hd)
    pos = jnp.arange(sq)
    out = A.flash_attention(q, k, v, pos, pos, window=window,
                            q_chunk=32, kv_chunk=16)
    # reference: expand GQA to full heads
    q_full = q.reshape(b, sq, kv * g, hd)
    k_full = jnp.repeat(k, g, axis=2)
    v_full = jnp.repeat(v, g, axis=2)
    want = flash_attention_ref(q_full, k_full, v_full, causal=True,
                               window=window)
    got = out.reshape(b, sq, kv * g, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# Seeded stand-in for the old hypothesis property: (batch, seq, chunk)
# triples spanning ragged seq/chunk ratios, chunk > seq, chunk == seq,
# and odd sequence lengths — deterministic on a bare install.
@pytest.mark.parametrize("b,sq,chunk", [
    (1, 8, 8), (2, 17, 8), (1, 40, 16), (3, 33, 64),
    (4, 9, 32), (2, 39, 13)])
def test_flash_chunk_invariance(b, sq, chunk):
    rng = np.random.default_rng(b * 100 + sq)
    kv, g, hd = 2, 2, 8
    q = _rand(rng, b, sq, kv, g, hd)
    k = _rand(rng, b, sq, kv, hd)
    v = _rand(rng, b, sq, kv, hd)
    pos = jnp.arange(sq)
    o1 = A.flash_attention(q, k, v, pos, pos, q_chunk=chunk, kv_chunk=chunk)
    o2 = A.flash_attention(q, k, v, pos, pos, q_chunk=sq, kv_chunk=sq)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_decode_ring_buffer_positions():
    # slot s at step pos holds absolute position pos - ((pos - s) % w)
    w = 8
    for pos in (3, 7, 8, 13, 25):
        kpos = np.asarray(A.cache_positions(pos, w))
        assert kpos.max() == pos
        valid = kpos[kpos >= 0]
        assert len(set(valid)) == len(valid)
        assert all(pos - w < p <= pos for p in valid)


def test_decode_matches_forward_with_window(rng):
    """Stream tokens one-by-one through the ring cache and compare to the
    full windowed forward — validates rotation + masking end-to-end."""
    from repro.configs import get_smoke_config
    import dataclasses
    from repro.models import model as M
    import jax

    cfg = get_smoke_config("h2o-danube-3-4b")  # window 32
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 48  # longer than the window
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_full, _ = M.forward(params, {"tokens": toks}, cfg)

    caches = M.init_caches(cfg, b, max_len=64)
    outs = []
    for t in range(s):
        logits, caches = M.decode_step(params, toks[:, t:t + 1], caches, cfg)
        outs.append(logits)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(logits_full),
                               atol=2e-4, rtol=2e-4)
