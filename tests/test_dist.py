"""repro.dist API contract: sharding hints, group meshes, registry."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import make_matrix, run_multidevice_script


# --- sharding: hint / hint_tree --------------------------------------------


def test_hint_is_identity_outside_mesh_context():
    from repro.dist.sharding import current_rules, hint, hint_tree

    assert current_rules() is None
    x = jnp.ones((4, 8))
    assert hint(x, "batch", None) is x  # exact no-op, not a copy
    tree = {"w": x, "b": jnp.zeros((8,))}
    out = hint_tree(tree, {"w": ("batch", None), "b": (None,)})
    assert out["w"] is x and out["b"] is tree["b"]


def test_hint_constrains_inside_mesh_context():
    from repro.dist.sharding import (LogicalRules, activation_hints,
                                     current_rules, hint, hint_tree)
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(1, 1)
    rules = LogicalRules({"batch": "data", "feat": "model"}, mesh=mesh)

    def f(x):
        with activation_hints(rules):
            assert current_rules() is rules
            return hint(x, "batch", "feat")

    jaxpr = str(jax.make_jaxpr(f)(jnp.ones((4, 8))))
    assert "sharding_constraint" in jaxpr
    # values are untouched, only placement is constrained
    np.testing.assert_array_equal(np.asarray(f(jnp.ones((4, 8)))), 1.0)
    assert current_rules() is None  # context restored

    def g(tree):
        with activation_hints(rules):
            return hint_tree(tree, {"w": ("batch", "feat")})

    jaxpr = str(jax.make_jaxpr(g)({"w": jnp.ones((4, 8))}))
    assert "sharding_constraint" in jaxpr


def test_activation_hints_requires_mesh():
    from repro.dist.sharding import LogicalRules, activation_hints

    with pytest.raises(ValueError, match="mesh"):
        with activation_hints(LogicalRules({"batch": "data"})):
            pass


def test_logical_rules_resolution():
    from repro.dist.sharding import LogicalRules
    from jax.sharding import PartitionSpec as P

    rules = LogicalRules({"batch": ("pod", "data"), "mlp": "model",
                          "seq": None})
    assert rules.spec(("batch", "seq", "mlp")) == \
        P(("pod", "data"), None, "model")
    assert rules.spec("REPLICATED") == P()
    assert rules.spec(None) == P()
    # unknown logical names resolve to replicated, not an error
    assert rules.spec(("nonexistent",)) == P(None)
    # axes missing from the bound mesh are dropped at resolution time
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(1, 1)  # ("data", "model") only — no "pod"
    assert rules.spec(("batch", "mlp"), mesh=mesh) == P("data", "model")


def test_tree_shardings_structure():
    from repro.dist.sharding import arch_rules, tree_shardings
    from repro.launch.mesh import make_debug_mesh
    from repro.configs import get_smoke_config

    mesh = make_debug_mesh(1, 1)
    cfg = get_smoke_config("olmo-1b")
    rules = arch_rules(cfg, mesh, None)
    axes = {"w": ("embed", "vocab"), "scalars": "REPLICATED",
            "nested": {"b": ("batch", None)}, "skip": None}
    sh = tree_shardings(mesh, rules, axes)
    assert sh["skip"] is None
    assert isinstance(sh["w"], jax.sharding.NamedSharding)
    assert sh["scalars"].spec == jax.sharding.PartitionSpec()
    assert set(sh) == set(axes)


# --- grouped: zolo_group_mesh (needs 8 devices -> subprocess) ---------------

_MESH_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
from repro.dist import zolo_group_mesh

for r in (2, 4):
    mesh = zolo_group_mesh(r)
    assert mesh.shape == {"zolo": r, "sep": 8 // r}, (r, dict(mesh.shape))
    assert mesh.axis_names == ("zolo", "sep")
    assert mesh.devices.shape == (r, 8 // r)
try:
    zolo_group_mesh(3)  # 3 does not divide 8
except ValueError:
    pass
else:
    raise SystemExit("expected ValueError for r=3 on 8 devices")

# registry grouped routing: polar_svd(..., mesh=) must reach Algorithm 3
# through the ONE dispatch path (the README's distributed quickstart)
import repro.core as C
rng = np.random.default_rng(11)
m, n, kappa = 64, 32, 1e3
u, _ = np.linalg.qr(rng.standard_normal((m, n)))
v, _ = np.linalg.qr(rng.standard_normal((n, n)))
a = jnp.asarray(u @ np.diag(np.geomspace(1, 1 / kappa, n)) @ v.T)
mesh = zolo_group_mesh(2)
uu, s, vh = C.polar_svd(a, method="zolo_static", mesh=mesh,
                        l0=0.9 / kappa, r=2)
assert float(C.svd_residual(a, uu, s, vh)) < 1e-12
assert float(C.orthogonality(uu)) < 1e-13
# zolo_pd_static kwargs (qr_mode/qr_iters) must survive grouped routing
q, h, info = C.polar_decompose(a, method="zolo_grouped", mesh=mesh,
                               l0=0.9 / kappa, want_h=True,
                               qr_mode="chol", qr_iters=1)
assert int(info.iterations) >= 1
assert float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a)) < 1e-12
print("MESH_OK")
"""


def test_zolo_group_mesh_and_registry_routing_subprocess():
    run_multidevice_script(_MESH_SCRIPT, "MESH_OK", timeout=300)


def test_zolo_group_mesh_single_device_and_error_lists_divisors():
    """r == ndev is a valid degenerate mesh (sep axis of size 1) — the
    single-device CI case; invalid r errors name the valid divisors."""
    from repro.dist import zolo_group_mesh

    ndev = len(jax.devices())  # 1 in the main test process
    mesh = zolo_group_mesh(ndev)
    assert mesh.shape == {"zolo": ndev, "sep": 1}
    divisors = [d for d in range(1, ndev + 1) if ndev % d == 0]
    with pytest.raises(ValueError, match=str(divisors).replace("[", r"\[")):
        zolo_group_mesh(ndev + 7)
    with pytest.raises(ValueError, match="valid r"):
        zolo_group_mesh(0)


# --- registry ----------------------------------------------------------------


def test_registry_roundtrip_and_dispatch():
    import repro.core as C
    from repro.core import registry

    calls = []

    @registry.register_polar("_test_dummy", description="test-only")
    def dummy(a, **kw):
        calls.append(kw)
        q = jnp.eye(a.shape[-2], a.shape[-1], dtype=a.dtype)
        return q, None, C.PolarInfo(jnp.int32(0),
                                    jnp.asarray(0.0, a.dtype),
                                    jnp.asarray(1.0, jnp.float32))

    try:
        spec = registry.get_polar("_test_dummy")
        assert spec.fn is dummy and not spec.supports_grouped
        assert "_test_dummy" in registry.list_polar()
        # a *different* function under a taken name is rejected; the same
        # function (module reload) re-registers benignly
        with pytest.raises(ValueError, match="already registered"):
            registry.register_polar("_test_dummy")(lambda a, **kw: None)
        assert registry.register_polar("_test_dummy")(dummy) is dummy
        # dispatch through the ONE public path routes to the registration
        a = jnp.eye(4)
        q, h, _ = C.polar_decompose(a, method="_test_dummy", foo=7)
        assert calls == [{"foo": 7}]
        np.testing.assert_array_equal(np.asarray(q), np.eye(4))
        # non-grouped backends reject mesh= instead of ignoring it
        with pytest.raises(ValueError, match="grouped"):
            C.polar_decompose(a, method="_test_dummy", mesh=object())
    finally:
        registry.unregister_polar("_test_dummy")
    assert "_test_dummy" not in registry.list_polar()


def test_registry_unknown_names_raise():
    import repro.core as C
    from repro.core import registry

    with pytest.raises(ValueError, match="unknown polar method"):
        registry.get_polar("does_not_exist")
    with pytest.raises(ValueError, match="unknown polar method"):
        C.polar_decompose(jnp.eye(4), method="does_not_exist")
    with pytest.raises(ValueError, match="unknown eig method"):
        C.polar_svd(jnp.eye(4), eig_method="does_not_exist")
    # grouped-only backends demand a mesh
    with pytest.raises(ValueError, match="mesh"):
        C.polar_decompose(jnp.eye(4), method="zolo_grouped")


def test_registry_capability_flags():
    from repro.core import registry

    assert registry.get_polar("zolo_static").supports_grouped
    assert registry.get_polar("zolo_grouped").requires_mesh
    assert registry.get_polar("svd").is_oracle
    assert registry.get_polar("zolo").dynamic
    assert {"eigh", "jacobi"} <= set(registry.list_eig())


def test_registry_rejects_inconsistent_capabilities():
    from repro.core import registry

    # supports_grouped with nothing to dispatch to is a registration
    # error, not a runtime TypeError
    with pytest.raises(ValueError, match="grouped_fn"):
        registry.register_polar("_test_bad_grouped",
                                supports_grouped=True)(lambda a, **kw: None)
    # requires_mesh without grouped support can never be dispatched
    with pytest.raises(ValueError, match="unsatisfiable"):
        registry.register_polar("_test_bad_mesh",
                                requires_mesh=True)(lambda a, **kw: None)
    assert "_test_bad_grouped" not in registry.list_polar()
    assert "_test_bad_mesh" not in registry.list_polar()


# --- wide (m < n) polar / SVD ------------------------------------------------


@pytest.mark.parametrize("method", ["zolo", "qdwh"])
def test_polar_decompose_wide_right_factor(method):
    import repro.core as C

    m, n = 48, 96
    a = make_matrix(m, n, 1e4, seed=3)
    q, h, _ = C.polar_decompose(a, method=method)
    assert q.shape == (m, n) and h.shape == (n, n)
    # A = Q H with the re-oriented right factor
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert rec < 1e-12
    assert float(jnp.abs(h - h.T).max()) < 1e-13  # symmetric
    assert float(jnp.linalg.eigvalsh(h).min()) > -1e-12  # PSD
    # rows of Q orthonormal
    g = q @ q.T
    assert float(jnp.abs(g - jnp.eye(m)).max()) < 1e-12


def test_polar_svd_wide_reconstruction():
    import repro.core as C

    m, n = 40, 104
    a = make_matrix(m, n, 9.06e3, seed=7)
    u, s, vh = C.polar_svd(a, method="zolo")
    assert u.shape == (m, m) and s.shape == (m,) and vh.shape == (m, n)
    assert float(C.svd_residual(a, u, s, vh)) < 1e-12
    assert float(C.orthogonality(u)) < 1e-13
    assert float(C.orthogonality(vh.swapaxes(-1, -2))) < 1e-13
    assert bool(jnp.all(s[:-1] >= s[1:]))  # descending
    s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s_ref, atol=1e-12)
