"""Resilience layer: in-graph health verdicts, the escalation ladder,
fault injection, and the serving fault-tolerance paths (chaos test).

Maps to src/repro/resilience/README.md: every failure mode there has a
test here that injects it and asserts the documented recovery.
"""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as C
import repro.solver as S
from repro.core.registry import get_polar, register_polar
from repro.core.svd import PALLAS_F32_KAPPA_MAX
from repro.core.zolo import DEFAULT_OPS
from repro.resilience import (
    Backpressure,
    CircuitOpen,
    DeadlineExceeded,
    FutureTimeout,
    ServiceFaults,
    SolveFailure,
    default_orth_tol,
    escalation_ladder,
    faulty_ops,
    judge,
    judge_plan,
    solve_with_escalation,
)
from repro.resilience.health import SolveHealth
from repro.serve import ServiceConfig, SvdService
from repro.serve.scheduler import MicroBatchScheduler

from conftest import make_matrix


# --- satellite 2: the converged flag -----------------------------------------


def test_dynamic_driver_reports_nonconvergence():
    a = make_matrix(64, 48, kappa=1e10, seed=3)
    _, _, info = C.zolo_pd(a, want_h=False, max_iters=1)
    assert not bool(info.converged)
    _, _, info = C.zolo_pd(a, want_h=False)
    assert bool(info.converged)
    # kappa_est = 1/l_init tracks the true conditioning
    assert 1e8 < 1.0 / float(info.l_init) < 1e13


def test_polarinfo_defaults_backcompat():
    # three-field construction (out-of-tree backends, old tests) still
    # works; the defaults read as converged / unknown conditioning
    info = C.PolarInfo(jnp.int32(1), jnp.asarray(0.0), jnp.asarray(1.0))
    assert bool(info.converged)
    assert np.isnan(float(info.l_init))


# --- tentpole (a): in-graph health verdicts ----------------------------------


def test_svd_verified_healthy():
    a = make_matrix(64, 48, kappa=1e4, seed=0)
    p = S.plan(S.SvdConfig(kappa=1e4, l0_policy="estimate_at_plan"), a.shape, a.dtype)
    u, s, vh, health = p.svd_verified(a)
    verdict = judge_plan(p, health)
    assert verdict.ok, str(verdict)
    assert bool(health.finite)
    assert float(health.orth) < default_orth_tol(a.dtype)
    # the factors are the same ones svd() returns
    u0, s0, vh0 = p.svd(a)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s0))


def test_svd_batched_verified_leaves_carry_batch_axis():
    a = jnp.stack([make_matrix(48, 32, kappa=1e3, seed=i)
                   for i in range(3)])
    p = S.plan(S.SvdConfig(kappa=1e3, l0_policy="estimate_at_plan"), (48, 32), a.dtype)
    u, s, vh, health = p.svd_batched_verified(a)
    assert u.shape == (3, 48, 32)
    for leaf in health:
        assert leaf.shape[:1] == (3,)
    for i in range(3):
        entry = SolveHealth(health.finite[i], health.orth[i],
                            health.converged[i], health.kappa_est[i])
        assert judge_plan(p, entry).ok


def test_health_masks_null_space_columns():
    # a zero-padded (rank-deficient) matrix is every serving slot's
    # reality: null-space columns of U are an arbitrary completion and
    # must not fail the orthogonality check
    a = make_matrix(48, 24, kappa=1e3, seed=1)
    padded = jnp.zeros((64, 48), a.dtype).at[:48, :24].set(a)
    p = S.plan(S.SvdConfig(kappa=1e3, l0_policy="estimate_at_plan"), (64, 48), a.dtype)
    _, s, _, health = p.svd_verified(padded)
    verdict = judge_plan(p, health)
    assert verdict.ok, str(verdict)


def test_judge_reasons():
    bad = SolveHealth(finite=jnp.asarray(False),
                      orth=jnp.asarray(1.0, jnp.float32),
                      converged=jnp.asarray(False),
                      kappa_est=jnp.asarray(1e5, jnp.float32))
    v = judge(bad, orth_tol=1e-10, kappa_max=2e4)
    assert not v.ok and len(v.reasons) == 4
    # NaN orthogonality (NaN factors) must fail, not sail through
    nan_orth = bad._replace(finite=jnp.asarray(True),
                            orth=jnp.asarray(float("nan"), jnp.float32),
                            converged=jnp.asarray(True),
                            kappa_est=jnp.asarray(float("nan"),
                                                  jnp.float32))
    assert not judge(nan_orth, orth_tol=1e-10).ok


# --- satellite 3: the runtime kappa envelope ---------------------------------


def test_runtime_envelope_folded_into_verdict():
    class _Stub:
        config = S.SvdConfig(method="zolo", compute_dtype="float32")
        dtype = jnp.float32
        method = "zolo_pallas_dynamic"

    spec = get_polar("zolo_pallas_dynamic")
    assert spec.kappa_max_f32 is not None
    beyond = SolveHealth(finite=jnp.asarray(True),
                         orth=jnp.asarray(1e-6, jnp.float32),
                         converged=jnp.asarray(True),
                         kappa_est=jnp.asarray(spec.kappa_max_f32 * 10,
                                               jnp.float32))
    v = judge_plan(_Stub(), beyond)
    assert not v.ok and any("envelope" in r for r in v.reasons)
    inside = beyond._replace(
        kappa_est=jnp.asarray(spec.kappa_max_f32 / 10, jnp.float32))
    assert judge_plan(_Stub(), inside).ok
    # under f64 compute the f32 envelope does not apply

    class _StubF64(_Stub):
        config = S.SvdConfig(method="zolo", compute_dtype="float64")
        dtype = jnp.float64

    v64 = judge_plan(_StubF64(), beyond)
    assert not any("envelope" in r for r in v64.reasons)


# --- tentpole (b): the escalation ladder -------------------------------------


def test_ladder_derived_from_capability_flags():
    p = S.plan(S.SvdConfig(method="zolo_static", kappa=1e4,
               l0_policy="estimate_at_plan"),
               (64, 48), jnp.float64)
    ladder = escalation_ladder(p)
    reasons = [r for _, r in ladder]
    assert reasons[0] == "as planned"
    assert any("householder" in r for r in reasons)
    assert any("runtime conditioning" in r for r in reasons)
    # f64 plan: no f64 rung; consecutive configs never repeat
    assert not any("float64" in r for r in reasons)
    for (c1, _), (c2, _) in zip(ladder, ladder[1:]):
        assert c1 != c2
    # f32 compute adds the precision rung at the end
    p32 = S.plan(S.SvdConfig(method="zolo_static", kappa=1e4,
               l0_policy="estimate_at_plan"),
                 (64, 48), jnp.float32)
    assert escalation_ladder(p32)[-1][1] == "compute dtype -> float64"
    assert escalation_ladder(p32)[-1][0].compute_dtype == "float64"


def test_pallas_specs_declare_fallbacks():
    for name, fb in (("zolo_pallas", "zolo_static"),
                     ("zolo_pallas_dynamic", "zolo")):
        spec = get_polar(name)
        assert spec.fallback == fb
        assert spec.kappa_max_f32 == PALLAS_F32_KAPPA_MAX
    with pytest.raises(ValueError, match="loop"):
        register_polar("self_loop",
                       fallback="self_loop")(lambda a: None)


# --- tentpole (d) + (b): fault injection through the real ladder -------------


def test_faulty_ops_nan_recovers_up_the_ladder():
    a = make_matrix(64, 48, kappa=1e4, seed=2)
    ops = faulty_ops(nan_at_iter=0)
    cfg = S.SvdConfig(method="zolo", qr_mode="cholqr2",
                      extra=(("ops", ops),))
    u, s, vh, trail = solve_with_escalation(a, cfg)
    assert trail[0].outcome == "failed"
    assert not trail[0].verdict.ok
    assert trail[-1].outcome == "passed"
    s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s_ref[:48], atol=1e-8)


def test_faulty_ops_indefinite_gram_recovers():
    a = make_matrix(64, 48, kappa=1e4, seed=4)
    ops = faulty_ops(indefinite_at_iter=0)
    cfg = S.SvdConfig(method="zolo", qr_mode="chol",
                      extra=(("ops", ops),))
    u, s, vh, trail = solve_with_escalation(a, cfg)
    assert trail[0].outcome == "failed"
    assert trail[-1].outcome == "passed"


def test_exhausted_ladder_raises_solve_failure_with_trail():
    a = make_matrix(64, 48, kappa=1e4, seed=5)

    def broken(x, t, aw, mh):
        return DEFAULT_OPS.polar_update(x, t, aw, mh) * float("nan")

    cfg = S.SvdConfig(method="zolo",
                      extra=(("ops",
                              DEFAULT_OPS._replace(polar_update=broken)),))
    with pytest.raises(SolveFailure) as ei:
        solve_with_escalation(a, cfg)
    trail = ei.value.trail
    assert len(trail) >= 2
    assert all(t.outcome in ("failed", "plan-error") for t in trail)
    assert "non-finite" in str(ei.value)


def test_batched_input_rejected():
    with pytest.raises(ValueError, match="one \\(m, n\\) matrix"):
        solve_with_escalation(jnp.zeros((2, 8, 8)), S.SvdConfig())


# --- topk_adaptive escalates through the same ladder -------------------------


def test_topk_adaptive_records_ladder_trail():
    import repro.spectral as sp

    a = make_matrix(96, 64, kappa=1e4, seed=6)
    cfg = sp.TopKConfig(k=4, strategy="sketch", power_iters=0, tol=1e-10)
    plan = sp.plan_topk(cfg, (96, 64), a.dtype)
    # tol=0 forces the dense fallback; it must run verified and leave
    # the rung trail in info
    u, s, vh, info = plan.topk_adaptive(a, tol=0.0)
    assert info["escalated"]
    assert info["trail"][-1].outcome == "passed"
    s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s_ref[:4], atol=1e-9)
    assert u.shape == (96, 4) and vh.shape == (4, 64)


# --- serving fault tolerance -------------------------------------------------


def _fake_clock(t0=0.0):
    t = [t0]

    def clock():
        return t[0]

    return clock, t


def _mat(m, n, seed=0):
    return make_matrix(m, n, kappa=1e3, seed=seed)


def test_scheduler_drop_preserves_fifo():
    sched = MicroBatchScheduler(4, clock=lambda: 0.0)
    for i in range(5):
        sched.enqueue("k", i)
    dropped = sched.drop(lambda x: x % 2 == 1)
    assert dropped == [1, 3]
    assert sched.pending() == 3
    (_, items), = sched.ready(force=True)
    assert items == [0, 2, 4]


def test_dispatch_exception_fails_every_batched_future():
    # satellite 1: an exception inside _dispatch used to leave batched
    # futures pending forever
    faults = ServiceFaults(dispatch_error_batches=(0,))
    svc = SvdService(ServiceConfig(batch_size=2, faults=faults))
    svc.warmup([(48, 32)])
    f0, f1 = svc.submit(_mat(48, 32)), svc.submit(_mat(48, 32, seed=1))
    svc.flush()
    for f in (f0, f1):
        assert f.done()
        assert isinstance(f.exception(), RuntimeError)
        with pytest.raises(RuntimeError, match="injected dispatch fault"):
            f.result()
    assert svc.stats()["dispatch_errors"] == 1


def test_injected_nan_retries_on_next_rung_only_culprit():
    faults = ServiceFaults(nan_request_seqs=(1,))
    svc = SvdService(ServiceConfig(batch_size=2, max_retries=2,
                                   faults=faults))
    svc.warmup([(48, 32)])
    f0 = svc.submit(_mat(48, 32))
    f1 = svc.submit(_mat(48, 32, seed=1))
    svc.flush()
    u0, s0, _ = f0.result()
    u1, s1, _ = f1.result()          # recovered via the retry lane
    st = svc.stats()
    assert st["health_failures"] == 1 and st["retries"] == 1
    assert st["quarantined"] == 0
    # the retried entry is a genuine SVD of its clean input
    s_ref = np.linalg.svd(np.asarray(_mat(48, 32, seed=1)),
                          compute_uv=False)
    np.testing.assert_allclose(np.asarray(s1), s_ref, atol=1e-8)


def test_poison_request_quarantined_with_trail():
    svc = SvdService(ServiceConfig(batch_size=1, max_retries=2))
    svc.warmup([(48, 32)])
    poison = jnp.full((48, 32), float("nan"))
    f = svc.submit(poison)
    svc.flush()
    exc = f.exception()
    assert isinstance(exc, SolveFailure)
    assert len(exc.trail) == 3       # rung 0 + max_retries
    assert svc.stats()["quarantined"] == 1


def test_deadline_and_backpressure():
    clock, t = _fake_clock()
    svc = SvdService(ServiceConfig(batch_size=4, deadline=0.5,
                                   max_queue_depth=2), clock=clock)
    svc.warmup([(48, 32)])
    f0, f1 = svc.submit(_mat(48, 32)), svc.submit(_mat(48, 32, seed=1))
    with pytest.raises(Backpressure):
        svc.submit(_mat(48, 32, seed=2))
    t[0] = 1.0                        # both expire while queued
    svc.poll()
    for f in (f0, f1):
        assert isinstance(f.exception(), DeadlineExceeded)
        with pytest.raises(DeadlineExceeded):
            f.result()
    st = svc.stats()
    assert st["deadline_expired"] == 2 and st["shed"] == 1


def test_circuit_breaker_opens_and_cools_down():
    clock, t = _fake_clock()
    faults = ServiceFaults(dispatch_error_batches=tuple(range(8)))
    svc = SvdService(ServiceConfig(batch_size=1, breaker_threshold=2,
                                   breaker_cooldown=10.0, faults=faults),
                     clock=clock)
    for _ in range(2):
        svc.submit(_mat(48, 32))
        svc.poll(force=True)
    with pytest.raises(CircuitOpen):
        svc.submit(_mat(48, 32))
    st = svc.stats()
    assert st["circuit_opens"] == 1 and st["circuit_rejects"] == 1
    t[0] = 20.0                       # cooldown over: breaker closes
    svc.submit(_mat(48, 32))


def test_future_result_timeout(monkeypatch):
    clock, t = _fake_clock()
    svc = SvdService(ServiceConfig(batch_size=2), clock=clock)
    f = svc.submit(_mat(48, 32))
    # a scheduler that never dispatches: the future stays queued and
    # result(timeout=) must raise instead of spinning forever
    monkeypatch.setattr(svc._sched, "ready",
                        lambda now=None, force=False: [])
    with pytest.raises(FutureTimeout, match="still queued"):
        f.result(timeout=0.0)
    assert not f.done()               # still live: result() again is legal


def test_skewed_clock_ages_deadlines():
    clock, t = _fake_clock()
    faults = ServiceFaults(clock_skew=100.0)
    svc = SvdService(ServiceConfig(batch_size=4, faults=faults),
                     clock=clock)
    f = svc.submit(_mat(48, 32), deadline=50.0)  # already past, skewed
    assert f.t_submit == 100.0
    t[0] = 60.0
    svc.poll()
    assert isinstance(f.exception(), DeadlineExceeded)


# --- satellite 4: the chaos acceptance test ----------------------------------


def test_chaos_mixed_stream_drains_with_zero_hung_futures():
    """Mixed serve stream with injected NaN solves, dispatch exceptions
    and deadline-expired requests drains completely: every future
    resolves to a result or a typed error, none hang, and stats()
    accounts for each recovery path."""
    clock, t = _fake_clock()
    # dispatch order: batch 0 = [clean, nan-injected], batch 1 = retry
    # of the injected entry, batch 2 = the dispatch-error pair, then
    # the poison request's rung 0-2 solo batches
    faults = ServiceFaults(nan_request_seqs=(1,),
                           dispatch_error_batches=(2,))
    svc = SvdService(ServiceConfig(batch_size=2, max_retries=2,
                                   max_queue_depth=4,
                                   breaker_threshold=99, faults=faults),
                     clock=clock)
    svc.warmup([(48, 32)])

    futures = {}
    futures["ok"] = svc.submit(_mat(48, 32))                    # seq 0
    futures["injected"] = svc.submit(_mat(48, 32, seed=1))      # seq 1
    svc.flush()                                     # batches 0 and 1

    futures["derr_a"] = svc.submit(_mat(48, 32, seed=2))
    futures["derr_b"] = svc.submit(_mat(48, 32, seed=3))
    svc.flush()                                     # batch 2: raises

    futures["poison"] = svc.submit(jnp.full((48, 32), float("nan")))
    svc.flush()                                     # batches 3..5

    futures["late"] = svc.submit(_mat(48, 32, seed=4), deadline=0.5)
    t[0] = 1.0
    svc.flush()

    futures["tail"] = svc.submit(_mat(48, 32, seed=5))
    with pytest.raises(Backpressure):
        for _ in range(10):
            futures.setdefault("shed", svc.submit(_mat(48, 32, seed=6)))
            futures.pop("shed")
    svc.flush()

    # --- the acceptance bar: zero hung futures -----------------------
    assert all(f.done() for f in futures.values())
    assert svc.pending() == 0 and svc.stats()["inflight"] == 0

    for name in ("ok", "injected", "tail"):
        u, s, vh, = futures[name].result()
        assert np.all(np.isfinite(np.asarray(s)))
        assert futures[name].exception() is None
    assert isinstance(futures["derr_a"].exception(), RuntimeError)
    assert isinstance(futures["derr_b"].exception(), RuntimeError)
    assert isinstance(futures["poison"].exception(), SolveFailure)
    assert len(futures["poison"].exception().trail) == 3
    assert isinstance(futures["late"].exception(), DeadlineExceeded)

    st = svc.stats()
    assert st["retries"] == 3          # 1 injected + 2 poison climbs
    assert st["health_failures"] == 4  # injected rung 0 + poison x3
    assert st["quarantined"] == 1
    assert st["dispatch_errors"] == 1
    assert st["deadline_expired"] == 1
    assert st["shed"] >= 1
    # recovered entry is bit-for-bit a healthy solve of the clean input
    s_ref = np.linalg.svd(np.asarray(_mat(48, 32, seed=1)),
                          compute_uv=False)
    np.testing.assert_allclose(
        np.asarray(futures["injected"].result()[1]), s_ref, atol=1e-8)
