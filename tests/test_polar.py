"""Polar decomposition invariants across methods and conditioning."""

import numpy as np
import pytest
from _propcheck import given, settings, st

import jax.numpy as jnp
import repro.core as C

from conftest import make_matrix


def _check(a, q, h, orth_tol, rec_tol):
    n = a.shape[-1]
    orth = float(C.orthogonality(q))
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert orth < orth_tol, orth
    assert rec < rec_tol, rec
    # H symmetric PSD (up to rounding)
    assert float(jnp.abs(h - h.T).max()) < 1e-12
    w = np.linalg.eigvalsh(np.asarray(h))
    assert w.min() > -1e-10


@pytest.mark.parametrize("kappa", [1.3, 14.0, 9.06e3, 1e7, 3.46e11])
@pytest.mark.parametrize("method", ["zolo", "qdwh"])
def test_pd_invariants(kappa, method):
    a = make_matrix(120, 80, kappa, seed=3)
    q, h, info = C.polar_decompose(a, method=method, want_h=True)
    _check(a, q, h, 1e-13, 5e-13)


def test_iteration_counts_match_theory_and_paper():
    """With exact (alpha, l) the dynamic driver stops at the Table-1
    theoretical count; the paper's measured Table 5 (3/4 iterations for
    these matrices) reflects loose runtime estimates and is reproduced by
    the estimate-everything mode within +1 iteration."""
    from repro.core import coeffs as CF
    for kappa in (1.29, 14.0, 9.06e3):
        a = make_matrix(160, 120, kappa, seed=11)
        for r in (2, 3, 4):
            theory = CF.zolo_iter_count(kappa / 0.9, r)
            q, _, info = C.zolo_pd(a, r=r, alpha=1.0, l=0.9 / kappa,
                                   want_h=False)
            # residual stopping (the paper's own rule) = theory or +1,
            # exactly the relationship between its Tables 1 and 5/10
            assert theory <= int(info.iterations) <= theory + 1, (kappa, r)
            assert float(C.orthogonality(q)) < 1e-13
            q2, _, info2 = C.zolo_pd(a, r=r, want_h=False)  # estimates
            assert theory <= int(info2.iterations) <= theory + 2
            assert float(C.orthogonality(q2)) < 1e-13


def test_iteration_counts_match_paper_table10():
    """bcsstk18-class (kappa 3.46e11): paper Table 10 r=2 -> 4, r=4 -> 3
    (these match Table-1 theory exactly at this conditioning)."""
    a = make_matrix(160, 120, 3.46e11, seed=13)
    for r, iters in {2: 4, 4: 3}.items():
        q, _, info = C.zolo_pd(a, r=r, alpha=1.0, l=0.9 / 3.46e11,
                               want_h=False)
        assert int(info.iterations) == iters


def test_qdwh_iterations_bounded():
    a = make_matrix(120, 80, 1e16, seed=5)
    q, _, info = C.qdwh_pd(a, alpha=1.0, l=0.9e-16, want_h=False)
    # theory says 6; the residual stopping rule confirms with up to two
    # extra cheap Cholesky iterations
    assert int(info.iterations) <= 8
    assert float(C.orthogonality(q)) < 1e-13


def test_static_matches_dynamic():
    kappa = 1e4
    a = make_matrix(96, 64, kappa, seed=9)
    q_dyn, _, _ = C.zolo_pd(a, r=2, alpha=1.0, l=0.9 / kappa, want_h=False)
    q_st, _, _ = C.zolo_pd_static(a, l0=0.9 / kappa, r=2, want_h=False)
    # both are converged polar factors; they may stop at different
    # iteration counts, so agreement is at the residual level
    assert float(jnp.abs(q_dyn - q_st).max()) < 5e-8
    assert float(C.orthogonality(q_dyn)) < 1e-13
    assert float(C.orthogonality(q_st)) < 1e-13


def test_first_mode_variants_agree():
    kappa = 1e5
    a = make_matrix(100, 64, kappa, seed=2)
    qs = {}
    for mode in ("cholqr2", "householder"):
        q, _, _ = C.zolo_pd(a, r=3, alpha=1.0, l=0.9 / kappa,
                            first_mode=mode, want_h=False)
        qs[mode] = q
        assert float(C.orthogonality(q)) < 1e-13
    assert float(jnp.abs(qs["cholqr2"] - qs["householder"]).max()) < 1e-9


def test_unknown_iteration_modes_raise_value_error():
    """An unknown first_mode/qr_mode must fail up front with the valid
    choices, not leak a bare KeyError from the dispatch table."""
    a = make_matrix(32, 16, 10.0, seed=5)
    with pytest.raises(ValueError, match="first_mode.*'qr'"):
        C.zolo_pd(a, first_mode="qr")
    with pytest.raises(ValueError, match="qr_mode.*chol"):
        C.zolo_pd_static(a, l0=0.09, qr_mode="house")


def test_newton_square():
    a = make_matrix(90, 90, 1e6, seed=4)
    q, h, info = C.scaled_newton_pd(a)
    _check(a, q, h, 1e-13, 1e-12)


def test_wide_matrix_canonicalization():
    a = make_matrix(60, 100, 30.0, seed=8)
    q, _, _ = C.polar_decompose(a, method="qdwh", want_h=False)
    # polar factor of a wide matrix has orthonormal ROWS
    g = q @ q.T
    assert float(jnp.abs(g - jnp.eye(60)).max()) < 1e-13


@given(st.integers(min_value=3, max_value=10),
       st.integers(min_value=1, max_value=8),
       st.floats(min_value=1.0, max_value=8.0))
@settings(max_examples=6, deadline=None)
def test_property_polar(m8, n8, logk):
    m, n = 8 * m8 + 8, 8 * n8
    if n > m:
        m, n = n, m + 8
    kappa = 10.0 ** logk
    a = make_matrix(m, n, kappa, seed=m8 * 13 + n8)
    q, h, _ = C.zolo_pd(a, r=2, want_h=True)
    assert float(C.orthogonality(q)) < 1e-12
    assert float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a)) < 1e-11
