"""Paper Algorithm 3 (grouped shard_map Zolo-PD) on 8 host devices.

Runs in a subprocess so the main test process keeps 1 device."""

from conftest import run_multidevice_script

_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
import repro.core as C
from repro.dist import grouped_zolo_pd_static, zolo_group_mesh

rng = np.random.default_rng(5)
m, n, kappa = 256, 128, 9.06e3
u, _ = np.linalg.qr(rng.standard_normal((m, n)))
v, _ = np.linalg.qr(rng.standard_normal((n, n)))
a = jnp.asarray(u @ np.diag(np.geomspace(1, 1/kappa, n)) @ v.T)

for r in (2, 4):
    mesh = zolo_group_mesh(r)
    assert mesh.shape == {"zolo": r, "sep": 8 // r}
    q = grouped_zolo_pd_static(a, mesh=mesh, l0=0.9/kappa, r=r)
    h = C.form_h(q, a)
    orth = float(C.orthogonality(q))
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert orth < 1e-13, (r, orth)
    assert rec < 1e-12, (r, rec)
    # must agree with the single-jit batched (gram-shared) mode
    q2, _, _ = C.zolo_pd(a, r=r, l=0.9/kappa, want_h=False)
    assert float(jnp.abs(q - q2).max()) < 1e-10, r
print("GROUPED_OK")
"""


def test_grouped_zolo_subprocess():
    run_multidevice_script(_SCRIPT, "GROUPED_OK")
