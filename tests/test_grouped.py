"""Paper Algorithm 3 (grouped shard_map Zolo-PD) on 8 host devices.

Runs in subprocesses so the main test process keeps 1 device."""

from conftest import run_multidevice_script

_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
import repro.core as C
from repro.dist import grouped_zolo_pd_static, zolo_group_mesh

rng = np.random.default_rng(5)
m, n, kappa = 256, 128, 9.06e3
u, _ = np.linalg.qr(rng.standard_normal((m, n)))
v, _ = np.linalg.qr(rng.standard_normal((n, n)))
a = jnp.asarray(u @ np.diag(np.geomspace(1, 1/kappa, n)) @ v.T)

for r in (2, 4):
    mesh = zolo_group_mesh(r)
    assert mesh.shape == {"zolo": r, "sep": 8 // r}
    q = grouped_zolo_pd_static(a, mesh=mesh, l0=0.9/kappa, r=r)
    h = C.form_h(q, a)
    orth = float(C.orthogonality(q))
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert orth < 1e-13, (r, orth)
    assert rec < 1e-12, (r, rec)
    # must agree with the single-jit batched (gram-shared) mode
    q2, _, _ = C.zolo_pd(a, r=r, l=0.9/kappa, want_h=False)
    assert float(jnp.abs(q - q2).max()) < 1e-10, r
print("GROUPED_OK")
"""


def test_grouped_zolo_subprocess():
    run_multidevice_script(_SCRIPT, "GROUPED_OK")


# The active "sep" axis: one Zolotarev term spans ndev/r devices with the
# iterate row-sharded inside the group (the paper's SEP contexts).  The
# driver's in-body trace-time assert proves each device holds an
# (m_pad/sep, n) row block — if the shard_map specs replicated X over
# "sep" (the pre-activation behavior), the assert would fire and every
# call below would fail.  m = 260 is divisible by neither sep degree, so
# the zero-row padding path is exercised throughout.
_SEP_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
import repro.core as C
from repro.dist import grouped_zolo_pd_static, zolo_group_mesh

rng = np.random.default_rng(7)
m, n, kappa = 260, 96, 9.06e3
u, _ = np.linalg.qr(rng.standard_normal((m, n)))
v, _ = np.linalg.qr(rng.standard_normal((n, n)))
a = jnp.asarray(u @ np.diag(np.geomspace(1, 1/kappa, n)) @ v.T)
l0 = 0.9 / kappa

qs = {}
for r, sep in ((2, 4), (4, 2), (8, 1)):
    mesh = zolo_group_mesh(r)
    assert mesh.shape == {"zolo": r, "sep": sep}
    q = grouped_zolo_pd_static(a, mesh=mesh, l0=l0, r=r)
    qs[(r, sep)] = np.asarray(q)
    orth = float(C.orthogonality(q))
    h = C.form_h(q, a)
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert orth < 1e-13, (r, sep, orth)
    assert rec < 1e-12, (r, sep, rec)
    # sep>1 vs sep=1 parity at the same r: a degenerate mesh over the
    # first r devices runs each term on one device
    mesh1 = zolo_group_mesh(r, devices=jax.devices()[:r])
    assert mesh1.shape == {"zolo": r, "sep": 1}
    q1 = grouped_zolo_pd_static(a, mesh=mesh1, l0=l0, r=r)
    # outputs are committed to different device sets: compare via host
    assert float(np.abs(np.asarray(q) - np.asarray(q1)).max()) < 1e-10, \
        (r, sep)
    # and parity with the single-device batched driver
    qb, _, _ = C.zolo_pd_static(a, l0=l0, r=r)
    assert float(np.abs(np.asarray(q) - np.asarray(qb)).max()) < 1e-10, \
        (r, sep)

# the sep-distributed (r=2, sep=4) solve matches the fully task-parallel
# (r=8, sep=1) one and the single-device driver at polar-parity tolerance
# (all converge to the same orthogonal factor)
q_sd, _, _ = C.zolo_pd_static(a, l0=l0, r=2)
assert float(np.abs(qs[(2, 4)] - qs[(8, 1)]).max()) < 1e-10
assert float(np.abs(qs[(2, 4)] - np.asarray(q_sd)).max()) < 1e-10

# sep>1 rejects the non-distributable structured-Householder first term
try:
    grouped_zolo_pd_static(a, mesh=zolo_group_mesh(2), l0=l0, r=2,
                           qr_mode="householder")
except ValueError as e:
    assert "householder" in str(e)
else:
    raise AssertionError("sep>1 householder must raise")
print("SEP_OK")
"""


def test_grouped_sep_axis_subprocess():
    run_multidevice_script(_SEP_SCRIPT, "SEP_OK")


# The plan path on a sep>1 mesh: auto resolves to a grouped backend via
# the sep-aware cost model, the plan records the (r, sep) factorization,
# and the flop estimate is the per-device critical path.
_SEP_PLAN_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
import repro.core as C
import repro.solver as S
from repro.core import registry
from repro.dist import zolo_group_mesh

rng = np.random.default_rng(11)
m, n, kappa = 256, 128, 1e4
u, _ = np.linalg.qr(rng.standard_normal((m, n)))
v, _ = np.linalg.qr(rng.standard_normal((n, n)))
a = jnp.asarray(u @ np.diag(np.geomspace(1, 1/kappa, n)) @ v.T)

mesh = zolo_group_mesh(2)          # {"zolo": 2, "sep": 4}
cfg = S.SvdConfig(kappa=kappa, l0_policy="estimate_at_plan")
p = S.plan(cfg, a.shape, a.dtype, mesh=mesh)
assert p.mode == "grouped" and p.r == 2 and p.sep == 4, (p.mode, p.r, p.sep)
spec = registry.get_polar(p.method)
assert spec.supports_grouped and not spec.is_oracle
# the sep degree reaches the registered cost model: at fixed r the
# per-device estimate shrinks when the group spans more devices
kw = dict(r=2, kappa=kappa, grouped=True)
assert spec.flops_fn(m, n, sep=4, **kw) < spec.flops_fn(m, n, sep=1, **kw)
assert p.flops_estimate == spec.flops_fn(m, n, sep=4, **kw) / 2

q, h, info = p.polar(a)
assert float(C.orthogonality(q)) < 1e-13
rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
assert rec < 1e-12
t0 = S.trace_count()
p.polar(a)
assert S.trace_count() == t0, "repeated grouped polar retraced"

# invalid combinations fail at plan time, not at first execution
try:
    S.plan(cfg.replace(qr_mode="householder"), a.shape, a.dtype, mesh=mesh)
except ValueError as e:
    assert "householder" in str(e) and "sep" in str(e)
else:
    raise AssertionError("householder on a sep>1 mesh must fail at plan")
u_p, s_p, vh_p = p.svd(a)
s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
assert float(np.abs(np.asarray(s_p) - s_ref).max()) < 1e-11
print("SEP_PLAN_OK")
"""


def test_grouped_sep_plan_subprocess():
    run_multidevice_script(_SEP_PLAN_SCRIPT, "SEP_PLAN_OK")


# The dynamic grouped backend: runtime conditioning estimated
# sep-collectively in-graph, feeding in-graph Zolotarev coefficients —
# parity against the static grouped driver and the single-device dynamic
# driver on every (r, sep) factorization.  m = 260 is divisible by
# neither sep degree, so the zero-row padding path (including the padded
# in-graph sigma_min estimate) is exercised throughout.
_DYN_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
import repro.core as C
from repro.dist import (grouped_zolo_pd_dynamic, grouped_zolo_pd_static,
                        zolo_group_mesh)

rng = np.random.default_rng(13)
m, n, kappa = 260, 96, 9.06e3
u, _ = np.linalg.qr(rng.standard_normal((m, n)))
v, _ = np.linalg.qr(rng.standard_normal((n, n)))
a = jnp.asarray(u @ np.diag(np.geomspace(1, 1/kappa, n)) @ v.T)
l0 = 0.9 / kappa

q_sd, _, _ = C.zolo_pd(a, r=2, want_h=False)  # single-device dynamic
for r, sep in ((2, 4), (4, 2), (8, 1)):
    mesh = zolo_group_mesh(r)
    assert mesh.shape == {"zolo": r, "sep": sep}
    q, info = grouped_zolo_pd_dynamic(a, mesh=mesh, return_info=True)
    assert int(info.iterations) >= 1
    orth = float(C.orthogonality(q))
    assert orth < 1e-13, (r, sep, orth)
    h = C.form_h(q, a)
    rec = float(jnp.linalg.norm(q @ h - a) / jnp.linalg.norm(a))
    assert rec < 1e-12, (r, sep, rec)
    # parity vs the static grouped driver at the same (r, sep) and vs
    # the single-device dynamic driver (all converge to the polar factor)
    q_st = grouped_zolo_pd_static(a, mesh=mesh, l0=l0, r=r)
    assert float(np.abs(np.asarray(q) - np.asarray(q_st)).max()) < 1e-10, \
        (r, sep)
    q_dd, _, _ = C.zolo_pd(a, r=r, want_h=False)
    assert float(np.abs(np.asarray(q) - np.asarray(q_dd)).max()) < 1e-10, \
        (r, sep)
assert float(np.abs(np.asarray(
    grouped_zolo_pd_dynamic(a, mesh=zolo_group_mesh(2)))
    - np.asarray(q_sd)).max()) < 1e-10

# an explicit bound short-circuits the in-graph estimate but must agree
q_l = grouped_zolo_pd_dynamic(a, mesh=zolo_group_mesh(2), l=l0)
assert float(C.orthogonality(q_l)) < 1e-13

# householder first iteration: allowed on sep=1, rejected on sep>1
q_hh = grouped_zolo_pd_dynamic(a, mesh=zolo_group_mesh(8),
                               first_mode="householder")
assert float(C.orthogonality(q_hh)) < 1e-13
try:
    grouped_zolo_pd_dynamic(a, mesh=zolo_group_mesh(2),
                            first_mode="householder")
except ValueError as e:
    assert "first_mode" in str(e) and "sep" in str(e), e
else:
    raise AssertionError("sep>1 householder first_mode must raise")
print("DYN_OK")
"""


def test_grouped_dynamic_subprocess():
    run_multidevice_script(_DYN_SCRIPT, "DYN_OK")


# The dynamic grouped plan path: l0_policy='runtime' + mesh= resolves to
# zolo_grouped_dynamic on the (r, sep) mesh, and ONE compiled executable
# serves matrices of wildly different conditioning (kappa 1e2 and 1e10)
# with zero retraces between them — the adaptive kappa-driven execution
# the static schedule cannot provide.
_DYN_PLAN_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
import repro.core as C
import repro.solver as S
from repro.core import registry
from repro.dist import zolo_group_mesh

m, n = 260, 96
def mk(kappa, seed):
    rng = np.random.default_rng(seed)
    u, _ = np.linalg.qr(rng.standard_normal((m, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    return jnp.asarray(u @ np.diag(np.geomspace(1, 1/kappa, n)) @ v.T)

mesh = zolo_group_mesh(2)          # {"zolo": 2, "sep": 4}
p = S.plan(S.SvdConfig(l0_policy="runtime"), (m, n), jnp.float64,
           mesh=mesh)
assert p.method == "zolo_grouped_dynamic", p.method
assert p.mode == "grouped" and p.r == 2 and p.sep == 4, (p.r, p.sep)
assert p.schedule is None            # nothing precomputed: runtime l
assert "sep=4" in repr(p), repr(p)
spec = registry.get_polar(p.method)
assert spec.dynamic and spec.supports_grouped and spec.requires_mesh
assert p.flops_estimate is not None and p.flops_estimate > 0

a_easy, a_hard = mk(1e2, 1), mk(1e10, 2)
q1, h1, i1 = p.polar(a_easy)
t0 = S.trace_count()
q2, h2, i2 = p.polar(a_hard)
assert S.trace_count() == t0, "kappa change retraced the dynamic plan"
for name, (a_, q_, h_, i_) in {"easy": (a_easy, q1, h1, i1),
                               "hard": (a_hard, q2, h2, i2)}.items():
    assert float(C.orthogonality(q_)) < 1e-13, name
    rec = float(jnp.linalg.norm(q_ @ h_ - a_) / jnp.linalg.norm(a_))
    assert rec < 1e-12, (name, rec)
# the hard matrix genuinely needs more of the while_loop
assert int(i2.iterations) > int(i1.iterations), \
    (int(i1.iterations), int(i2.iterations))

# parity with the static grouped plan at a kappa both can handle
kappa = 9.06e3
a = mk(kappa, 3)
p_st = S.plan(S.SvdConfig(method="zolo_grouped", kappa=kappa,
                          l0_policy="estimate_at_plan"),
              (m, n), jnp.float64, mesh=mesh)
q_dyn = p.polar(a, want_h=False)[0]
q_st = p_st.polar(a, want_h=False)[0]
assert float(np.abs(np.asarray(q_dyn) - np.asarray(q_st)).max()) < 1e-10

# the full grouped dynamic SVD (Alg. 2 over Alg. 3, runtime kappa)
u_p, s_p, vh_p = p.svd(a)
s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)
assert float(np.abs(np.asarray(s_p) - s_ref).max()) < 1e-11

# auto with a known l0 stays on the cheaper static schedule; the
# dynamic backend's margin (runtime estimate + safety iteration) is
# visible in the registered cost models
p_auto = S.plan(S.SvdConfig(kappa=kappa, l0_policy="estimate_at_plan"),
                (m, n), jnp.float64, mesh=mesh)
assert not registry.get_polar(p_auto.method).dynamic, p_auto.method
kw = dict(r=2, kappa=kappa, grouped=True, sep=4)
assert registry.get_polar("zolo_grouped_dynamic").flops_fn(m, n, **kw) > \
    registry.get_polar("zolo_grouped").flops_fn(m, n, **kw)

# capability errors name only mesh-compatible backends
try:
    S.plan(S.SvdConfig(method="zolo_grouped", l0_policy="runtime"),
           (m, n), jnp.float64, mesh=mesh)
except ValueError as e:
    assert "zolo_grouped_dynamic" in str(e), e
    assert "'zolo'" not in str(e) and "qdwh" not in str(e), e
else:
    raise AssertionError("static grouped + runtime l0 must fail at plan")
print("DYN_PLAN_OK")
"""


def test_grouped_dynamic_plan_subprocess():
    run_multidevice_script(_DYN_PLAN_SCRIPT, "DYN_PLAN_OK")
