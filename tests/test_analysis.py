"""repro.analysis: the AST invariant linter (per-rule good/bad fixtures,
suppressions, the baseline lifecycle, the CLI) and the jaxpr plan auditor
(dense/grouped/top-k green paths, the seeded double-psum regression, and
the SvdService stats wiring)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

import jax.numpy as jnp

import repro.solver as S
from repro.analysis import all_rules, run_lint, write_baseline
from repro.analysis import jaxpr_audit as JA
from repro.dist import zolo_group_mesh
from repro.serve import ServiceConfig, SvdService
from repro.spectral import TopKConfig, plan_topk

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint(tmp_path, source, rule, baseline=None):
    """Lint one dedented fixture snippet with a single rule."""
    f = tmp_path / "fixture.py"
    f.write_text(textwrap.dedent(source))
    return run_lint([str(f)], rules=[rule], baseline=baseline)


# --- per-rule fixtures: each bad snippet is the historical bug ------------


def test_rule_registry_complete():
    assert set(all_rules()) == {
        "collective-axis", "accum-dtype", "plan-key-hygiene",
        "retrace-hazard", "bare-assert", "keyerror-dispatch",
        "kernel-accum-envelope"}
    for rule in all_rules().values():
        assert rule.doc  # every rule documents its bug class


def test_collective_axis_flags_undeclared_literal(tmp_path):
    res = lint(tmp_path, """
        import jax
        AXIS_NAMES = ("zolo", "sep")
        def f(x):
            return jax.lax.psum(x, "spe")  # typo for "sep"
        """, "collective-axis")
    assert len(res.findings) == 1
    assert "'spe'" in res.findings[0].message
    assert "sep" in res.findings[0].message  # names the known axes


def test_collective_axis_accepts_declared_axes(tmp_path):
    res = lint(tmp_path, """
        import jax
        from jax.sharding import Mesh
        def make(devs):
            return Mesh(devs, ("zolo", "sep"))
        def f(x):
            return jax.lax.psum(x, "sep") + jax.lax.axis_index("zolo")
        def g(x, axis="sep"):  # parameter default also declares
            return jax.lax.psum(x, axis)
        """, "collective-axis")
    assert res.findings == []


def test_collective_axis_check_rep_needs_justification(tmp_path):
    bad = lint(tmp_path, """
        from jax.experimental.shard_map import shard_map
        def run(f, mesh, specs):
            return shard_map(f, mesh, in_specs=specs, out_specs=specs,
                             check_rep=False)
        """, "collective-axis")
    assert len(bad.findings) == 1
    assert "check_rep" in bad.findings[0].message
    good = lint(tmp_path, """
        from jax.experimental.shard_map import shard_map
        def run(f, mesh, specs):
            # check_rep=False: the rep checker rejects the one-hot xw
            # combine; the psum budget is enforced by the jaxpr audit
            return shard_map(f, mesh, in_specs=specs, out_specs=specs,
                             check_rep=False)
        """, "collective-axis")
    assert good.findings == []


def test_accum_dtype_flags_unpinned_gram(tmp_path):
    res = lint(tmp_path, """
        import jax.numpy as jnp
        def gram_chol(x):
            g = jnp.einsum("mk,mn->kn", x, x)
            return jnp.linalg.cholesky(g)
        """, "accum-dtype")
    assert len(res.findings) == 1
    assert "einsum" in res.findings[0].message
    assert "preferred_element_type" in res.findings[0].message


def test_accum_dtype_accepts_pinned_or_sinkless(tmp_path):
    res = lint(tmp_path, """
        import jax.numpy as jnp
        def gram_chol(x):
            g = jnp.einsum("mk,mn->kn", x, x,
                           preferred_element_type=jnp.float32)
            return jnp.linalg.cholesky(g.astype(x.dtype))
        def plain_product(x):  # no factorization sink: not a Gram
            return jnp.matmul(x, x.T)
        """, "accum-dtype")
    assert res.findings == []


def test_plan_key_hygiene_flags_mutable_config(tmp_path):
    res = lint(tmp_path, """
        import dataclasses
        from typing import List
        @dataclasses.dataclass
        class SolveConfig:
            sizes: List[int]
        """, "plan-key-hygiene")
    msgs = [f.message for f in res.findings]
    assert len(msgs) == 2
    assert any("frozen" in m for m in msgs)
    assert any("sizes" in m for m in msgs)


def test_plan_key_hygiene_accepts_frozen_tuple_config(tmp_path):
    res = lint(tmp_path, """
        import dataclasses
        from typing import Tuple
        @dataclasses.dataclass(frozen=True)
        class SolveConfig:
            sizes: Tuple[int, ...] = ()
        @dataclasses.dataclass
        class _ScratchConfig:  # private: not a cache key
            buf: list = None
        @dataclasses.dataclass
        class Runner:  # not *Config/*Policy/*Key-suffixed
            log: list = None
        """, "plan-key-hygiene")
    assert res.findings == []


def test_retrace_hazard_flags_traced_branch_and_coercion(tmp_path):
    res = lint(tmp_path, """
        import jax
        @jax.jit
        def f(x, n):
            if n > 2:
                return float(x)
            return x
        """, "retrace-hazard")
    msgs = [f.message for f in res.findings]
    assert len(msgs) == 2
    assert any("Python `if`" in m for m in msgs)
    assert any("float()" in m for m in msgs)


def test_retrace_hazard_respects_static_argnames(tmp_path):
    res = lint(tmp_path, """
        import functools
        import jax
        @functools.partial(jax.jit, static_argnames=("n",))
        def g(x, n):
            if n > 2:          # n is static: branch is fine
                return x * 2
            if x.ndim > 2:     # .ndim/.shape are static attributes
                return x.sum()
            return x
        """, "retrace-hazard")
    assert res.findings == []


def test_bare_assert_flagged(tmp_path):
    res = lint(tmp_path, """
        def f(x):
            assert x > 0
            return x
        """, "bare-assert")
    assert len(res.findings) == 1
    assert "-O" in res.findings[0].message


def test_keyerror_dispatch_flags_unguarded_table(tmp_path):
    bad = lint(tmp_path, """
        TABLE = {"zolo": 1, "qdwh": 2}
        def pick(name):
            return TABLE[name]
        """, "keyerror-dispatch")
    assert len(bad.findings) == 1
    assert "TABLE[name]" in bad.findings[0].message
    good = lint(tmp_path, """
        TABLE = {"zolo": 1, "qdwh": 2}
        def pick(name):
            if name not in TABLE:
                raise ValueError(f"unknown {name!r}; known: {sorted(TABLE)}")
            return TABLE[name]
        """, "keyerror-dispatch")
    assert good.findings == []


# --- engine mechanics: suppression, baseline lifecycle, CLI ---------------


def test_inline_suppression(tmp_path):
    res = lint(tmp_path, """
        def f(x):
            # repro-lint: disable=bare-assert -- test-only helper
            assert x > 0
            return x
        """, "bare-assert")
    assert res.findings == [] and res.suppressed == 1


def test_baseline_lifecycle(tmp_path):
    src = "def f(x):\n    assert x > 0\n    return x\n"
    fix = tmp_path / "mod.py"
    fix.write_text(src)
    base = tmp_path / "baseline.json"

    first = run_lint([str(fix)], rules=["bare-assert"])
    assert len(first.findings) == 1
    write_baseline(str(base), first.findings)

    # baselined finding rides; nothing new fails
    second = run_lint([str(fix)], rules=["bare-assert"], baseline=str(base))
    assert second.ok and second.findings == [] and len(second.baselined) == 1

    # a NEW violation still fails against the same baseline
    fix.write_text(src + "\ndef g(y):\n    assert y\n    return y\n")
    third = run_lint([str(fix)], rules=["bare-assert"], baseline=str(base))
    assert not third.ok and len(third.findings) == 1

    # fixing the original flags its baseline entry as stale
    fix.write_text("def f(x):\n    return x\n")
    fourth = run_lint([str(fix)], rules=["bare-assert"], baseline=str(base))
    assert fourth.ok and fourth.stale_baseline == [
        first.findings[0].fingerprint()]


def test_fingerprint_is_line_independent(tmp_path):
    fix = tmp_path / "mod.py"
    fix.write_text("def f(x):\n    assert x\n    return x\n")
    a = run_lint([str(fix)], rules=["bare-assert"]).findings[0]
    fix.write_text("\n\n\ndef f(x):\n    assert x\n    return x\n")
    b = run_lint([str(fix)], rules=["bare-assert"]).findings[0]
    assert a.line != b.line and a.fingerprint() == b.fingerprint()


def _run_cli(args):
    env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=ROOT, env=env, timeout=120)


def test_cli_json_and_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(x):\n    assert x\n    return x\n")
    out = _run_cli([str(bad), "--format=json"])
    assert out.returncode == 1, out.stderr
    data = json.loads(out.stdout)
    assert data["ok"] is False and data["files"] == 1
    assert data["findings"][0]["rule"] == "bare-assert"

    good = tmp_path / "good.py"
    good.write_text("def f(x):\n    return x\n")
    out = _run_cli([str(good), "--format=json"])
    assert out.returncode == 0, out.stdout + out.stderr
    assert json.loads(out.stdout)["ok"] is True

    out = _run_cli(["--list-rules"])
    assert out.returncode == 0
    assert "collective-axis" in out.stdout and "bare-assert" in out.stdout


def test_source_tree_is_lint_clean():
    """The acceptance criterion: the shipped tree carries zero findings
    (every historical violation was fixed, not baselined away)."""
    res = run_lint([os.path.join(ROOT, "src", "repro")])
    assert res.errors == []
    assert res.findings == [], "\n".join(f.render() for f in res.findings)
    assert res.files > 50  # sanity: the walk actually saw the tree


# --- jaxpr plan auditor ---------------------------------------------------


def test_expected_psum_model():
    # static: qr_iters * cost(qr_mode) + (I - qr_iters) Grams, I combines
    st = JA.expected_grouped_psums(
        "zolo_grouped",
        {"schedule": (0.0,) * 5, "qr_mode": "cholqr2", "qr_iters": 1})
    assert st == {"sep": 6, "zolo": 5}
    hh = JA.expected_grouped_psums(
        "zolo_grouped", {"schedule": (0.0,) * 3, "qr_mode": "householder"})
    assert hh == {"sep": 2, "zolo": 3}
    # dynamic: in-graph estimate + peeled 3-branch first iter + residuals
    # (each residual is ONE fused fnorm_pair psum — two norms ride a
    # single length-2 all-reduce; body = 1 Gram + 1 fnorm_pair)
    dy = JA.expected_grouped_psums(
        "zolo_grouped_dynamic", {"first_mode": "auto"}, sep=1)
    assert dy == {"sep": 7, "zolo": 4}
    # pinned l skips the estimate Gram; sep>1 swaps householder out
    dy2 = JA.expected_grouped_psums(
        "zolo_grouped_dynamic", {"first_mode": "auto", "l": 1e-3}, sep=4)
    assert dy2 == {"sep": 8, "zolo": 4}
    assert JA.expected_grouped_psums("zolo_static", {}) is None


def test_audit_dense_plan_green():
    p = S.plan(S.SvdConfig(method="zolo_static", l0=0.9 / 1e3, r=2),
               (48, 32), jnp.float64)
    rep = p.audit()
    assert rep.ok
    assert rep.psum_counts == {} and rep.axis_names == ()
    assert rep.callbacks == ()
    assert "collective-axis-validity" in rep.checks


def test_audit_static_grouped_plan_green():
    p = S.plan(S.SvdConfig(method="zolo_grouped", kappa=9.06e3,
                           l0_policy="estimate_at_plan"),
               (64, 32), jnp.float64, mesh=zolo_group_mesh(1))
    rep = p.audit()
    assert rep.ok and "psum-count" in rep.checks
    want = JA.expected_grouped_psums(p.method, p._backend_kwargs,
                                     sep=p.sep)
    assert rep.psum_counts == want
    assert want["zolo"] == len(p.schedule)  # one combine per iteration


def test_audit_dynamic_grouped_plan_green():
    p = S.plan(S.SvdConfig(l0_policy="runtime"), (64, 32), jnp.float64,
               mesh=zolo_group_mesh(1))
    assert p.method == "zolo_grouped_dynamic"
    rep = p.audit()
    assert rep.ok and set(rep.psum_counts) == {"sep", "zolo"}


def test_audit_topk_plan_green():
    p = plan_topk(TopKConfig(k=4, kappa=1e4), (96, 48))
    rep = p.audit()
    assert rep.ok
    # non-grouped contract: a top-k graph owes the mesh nothing
    assert rep.psum_counts == {} and rep.axis_names == ()


def test_audit_rejects_double_reduced_gram(monkeypatch):
    """The PR 4 regression, reintroduced on purpose: a bundle whose
    gram_local all-reduces makes CholeskyQR2's Q2-Gram psum twice, and
    the audit must reject the plan with the double-psum diagnosis."""
    from repro.dist import grouped_ops as gops
    from repro.solver import planner as planner_mod

    real = gops.sep_reduce_ops

    def double_reduced(base=None, *, axis="sep"):
        ops = real(base, axis=axis)
        return ops._replace(gram_local=ops.gram)

    monkeypatch.setattr(gops, "sep_reduce_ops", double_reduced)
    p = S.plan(S.SvdConfig(method="zolo_grouped", kappa=3.7e3,
                           l0_policy="estimate_at_plan"),
               (64, 32), jnp.float64, mesh=zolo_group_mesh(1))
    try:
        with pytest.raises(JA.AuditError) as ei:
            p.audit()
        report = ei.value.report
        assert not report.ok
        joined = "\n".join(report.violations)
        assert "'sep'" in joined and "gram_local" in joined
        # non-raising mode returns the same report for CI tabulation
        again = p.audit(raise_on_fail=False)
        assert again.violations == report.violations
    finally:
        # drop the deliberately-broken plan so the session-end
        # audit_all_plans sweep (REPRO_AUDIT_PLANS=1) stays green
        for key in [k for k, v in planner_mod._PLANS.items() if v is p]:
            del planner_mod._PLANS[key]


def test_audit_rejects_non_plan_object():
    with pytest.raises(TypeError, match="neither _svd_impl nor _impl"):
        JA.audit_plan(object())


def test_audit_all_plans_green_after_suite():
    failures = JA.audit_all_plans(raise_on_fail=False)
    assert failures == [], failures


def test_service_stats_report_plan_audits():
    before = JA.audit_stats()
    svc = SvdService(ServiceConfig(batch_size=2, max_wait=0.0,
                                   audit_plans=True))
    svc.warmup([(48, 32)])
    audits = svc.stats()["plan_audits"]
    assert audits["audited"] >= 1 and audits["failed"] == 0
    assert audits["passed"] == audits["audited"]
    after = JA.audit_stats()  # module counters are monotonic
    assert after["audited"] - before["audited"] >= audits["audited"]


def test_service_audit_off_by_default():
    svc = SvdService(ServiceConfig(batch_size=2, max_wait=0.0))
    svc.warmup([(48, 32)])
    assert svc.stats()["plan_audits"]["audited"] == 0
