"""Required per-arch smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro import configs as CFG
from repro import models as M
from repro.data.pipeline import SyntheticLM
from repro.models.config import SHAPES, ShapeConfig
from repro.optim.muon import MuonConfig
from repro.train.step import make_train_step

ARCHS = CFG.list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = CFG.get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("smoke", "train", 64, 2)
    batch = CFG.input_specs(cfg, shape, abstract=False)
    logits, aux = M.forward(params, batch, cfg)
    assert logits.shape == (2, 64, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = CFG.get_smoke_config(arch)
    init_fn, step_fn = make_train_step(cfg, MuonConfig(lr=0.01))
    state = init_fn(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg.vocab_size, 64, 2,
                       num_prefix_embeds=cfg.num_prefix_embeds,
                       d_model=cfg.d_model, dtype=cfg.dtype)
    jstep = jax.jit(step_fn)
    state, metrics = jstep(state, data.batch_at(0))
    assert int(state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    state, metrics2 = jstep(state, data.batch_at(1))
    assert np.isfinite(float(metrics2["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    cfg = CFG.get_smoke_config(arch)
    if cfg.num_experts:
        # capacity dropping depends on batch composition; disable drops
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 32
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)),
                       jnp.int32)
    batch = {"tokens": toks[:, :s]}
    if cfg.num_prefix_embeds:
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_prefix_embeds, cfg.d_model)),
            jnp.float32)
    logits_pre, caches = M.prefill(params, batch, cfg, max_len=128)
    logits_dec, _ = M.decode_step(params, toks[:, s:s + 1], caches, cfg)
    full = dict(batch)
    full["tokens"] = toks
    logits_full, _ = M.forward(params, full, cfg)
    p = cfg.num_prefix_embeds
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, s - 1 + p]),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, s + p]),
                               atol=5e-5, rtol=5e-5)


@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-2b",
                                  "h2o-danube-3-4b"])
def test_subquadratic_flag(arch):
    assert CFG.get_config(arch).sub_quadratic


@pytest.mark.parametrize("arch", ["yi-34b", "qwen3-8b", "dbrx-132b",
                                  "musicgen-large", "pixtral-12b"])
def test_full_attention_skips_long(arch):
    cfg = CFG.get_config(arch)
    assert not cfg.sub_quadratic
    assert CFG.registry.cell_supported(cfg, SHAPES["long_500k"]) is not None


def test_full_configs_match_assignment():
    spec = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for arch, (nl, d, h, kv, ff, v) in spec.items():
        c = CFG.get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (nl, d, h, kv, ff, v), arch
    m = CFG.get_config("mamba2-130m")
    assert (m.num_layers, m.d_model, m.vocab_size, m.ssm_state) == \
        (24, 768, 50280, 128)
    assert CFG.get_config("moonshot-v1-16b-a3b").num_experts == 64
    assert CFG.get_config("moonshot-v1-16b-a3b").moe_top_k == 6
    assert CFG.get_config("dbrx-132b").num_experts == 16
    assert CFG.get_config("dbrx-132b").moe_top_k == 4
