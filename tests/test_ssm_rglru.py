"""SSD chunked scan and RG-LRU vs naive sequential recurrences."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from repro.models import rglru as R
from repro.models import ssm as S


def _naive_ssd(x, dt, a, b, c):
    """Sequential SSM recurrence oracle (f64)."""
    bt, s, h, p = x.shape
    n = b.shape[-1]
    x, dt, b, c = (np.asarray(v, np.float64) for v in (x, dt, b, c))
    a = np.asarray(a, np.float64)
    state = np.zeros((bt, h, p, n))
    ys = []
    for t in range(s):
        dec = np.exp(-a[None, :] * dt[:, t])  # (bt, h)
        state = state * dec[..., None, None] + np.einsum(
            "bn,bhp->bhpn", b[:, t], x[:, t] * dt[:, t][..., None])
        ys.append(np.einsum("bn,bhpn->bhp", c[:, t], state))
    return np.stack(ys, axis=1), state


@pytest.mark.parametrize("s,chunk", [(32, 8), (40, 16), (64, 64), (16, 32)])
def test_ssd_scan_matches_recurrence(s, chunk, rng):
    bt, h, p, n = 2, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((bt, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.random((bt, s, h)) * 0.5, jnp.float32)
    a = jnp.asarray(rng.random(h) * 2 + 0.5, jnp.float32)
    b = jnp.asarray(rng.standard_normal((bt, s, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((bt, s, n)), jnp.float32)
    y, state = S.ssd_scan(x, dt, a, b, c, chunk)
    y0, state0 = _naive_ssd(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), y0, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), state0, atol=1e-4,
                               rtol=1e-4)


def test_ssd_chunk_invariance(rng):
    bt, s, h, p, n = 1, 48, 2, 4, 3
    args = (jnp.asarray(rng.standard_normal((bt, s, h, p)), jnp.float32),
            jnp.asarray(rng.random((bt, s, h)) * 0.3, jnp.float32),
            jnp.asarray(rng.random(h) + 0.5, jnp.float32),
            jnp.asarray(rng.standard_normal((bt, s, n)), jnp.float32),
            jnp.asarray(rng.standard_normal((bt, s, n)), jnp.float32))
    y1, s1 = S.ssd_scan(*args, 8)
    y2, s2 = S.ssd_scan(*args, 24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


def test_ssd_decode_streaming_matches_forward(rng):
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("mamba2-130m")
    params = S.ssd_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 24
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    y_full, (state_full, _) = S.ssd_forward(params, x, cfg)
    cache = S.init_ssd_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        y, cache = S.ssd_decode(params, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_stream = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_stream), np.asarray(y_full),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(cache[0]), np.asarray(state_full),
                               atol=2e-4, rtol=2e-4)


def test_rglru_assoc_scan_matches_sequential(rng):
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("recurrentgemma-2b")
    params = R.rglru_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    b, s = 2, 20
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    y_full, (state, _) = R.rglru_forward(params, x, cfg)
    cache = R.init_rglru_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        y, cache = R.rglru_decode(params, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_stream = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_stream), np.asarray(y_full),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(cache[0]), np.asarray(state),
                               atol=2e-4, rtol=2e-4)
