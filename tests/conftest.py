import os

# Core numerics (Zolotarev coefficients, ill-conditioned PD) are validated
# in f64.  Model code pins its dtypes explicitly, so enabling x64 here is
# safe.  NOTE: device count stays 1 — only launch/dryrun.py forces 512.
os.environ.setdefault("JAX_ENABLE_X64", "1")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


def make_matrix(m, n, kappa, dtype=jnp.float64, seed=0, spectrum="geom"):
    """Random matrix with exact kappa_2 (geometric spectrum, Haar U/V)."""
    rng = np.random.default_rng(seed)
    k = min(m, n)
    u, _ = np.linalg.qr(rng.standard_normal((m, k)))
    v, _ = np.linalg.qr(rng.standard_normal((n, k)))
    if spectrum == "geom":
        s = np.geomspace(1.0, 1.0 / kappa, k)
    else:
        s = np.linspace(1.0, 1.0 / kappa, k)
    return jnp.asarray(u @ np.diag(s) @ v.T, dtype=dtype)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session", autouse=True)
def audit_plans_at_teardown():
    """Opt-in jaxpr audit of every plan the suite built.

    With ``REPRO_AUDIT_PLANS=1`` (the CI jaxpr-audit job), session
    teardown walks the solver and spectral plan caches through
    ``repro.analysis.jaxpr_audit.audit_all_plans`` — whatever graphs the
    tests exercised get their psum/dtype/callback invariants checked for
    free, without each test opting in.
    """
    yield
    if os.environ.get("REPRO_AUDIT_PLANS") != "1":
        return
    from repro.analysis.jaxpr_audit import audit_all_plans

    failures = audit_all_plans(raise_on_fail=False)
    assert not failures, f"plan audits failed at session end: {failures}"


def run_multidevice_script(script: str, marker: str, *, devices: int = 8,
                           timeout: int = 600) -> None:
    """Run ``script`` in a subprocess with ``devices`` virtual host devices
    and assert it printed ``marker``.

    Multi-device tests must run out-of-process: XLA_FLAGS is read once at
    jax import, and the main test process stays at 1 device.  The script
    gets x64, ``src`` on sys.path, and the repo root as cwd.
    """
    import subprocess
    import sys

    prelude = (
        "import os\n"
        f'os.environ["XLA_FLAGS"] = '
        f'"--xla_force_host_platform_device_count={devices}"\n'
        'os.environ["JAX_ENABLE_X64"] = "1"\n'
        "import sys\n"
        'sys.path.insert(0, "src")\n'
    )
    out = subprocess.run([sys.executable, "-c", prelude + script],
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))),
                         timeout=timeout)
    assert marker in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
