"""Elliptic functions vs scipy (the Zolotarev coefficient substrate)."""

import numpy as np
import pytest
import scipy.special as sp
from _propcheck import given, settings, st

import jax.numpy as jnp
from repro.core import elliptic as el


@given(st.floats(min_value=1e-6, max_value=0.999))
@settings(max_examples=20, deadline=None)
def test_ellipk_vs_scipy(l):
    mc = l * l
    ref = sp.ellipkm1(mc)
    got = float(el.ellipk_mc(jnp.float64(mc)))
    assert abs(got - ref) / ref < 1e-13


@given(st.floats(min_value=1e-6, max_value=0.95),
       st.floats(min_value=0.05, max_value=0.95))
@settings(max_examples=20, deadline=None)
def test_ellipj_vs_scipy(l, frac):
    mc = l * l
    m = 1.0 - mc
    kp = sp.ellipkm1(mc)
    u = frac * kp
    sn_r, cn_r, dn_r, _ = sp.ellipj(u, m)
    sn, cn, dn = el.ellipj_mc(jnp.float64(u), jnp.float64(mc))
    assert abs(float(sn) - sn_r) < 5e-11
    assert abs(float(cn) - cn_r) < 5e-11
    assert abs(float(dn) - dn_r) < 5e-11


def test_extreme_modulus_degenerates_to_tanh():
    # kappa = 1e12 regime: m -> 1, sn -> tanh, cn -> sech
    l = 1e-12
    mc = l * l
    kp = float(el.ellipk_mc(jnp.float64(mc)))
    for frac in (0.1, 0.5, 0.9):
        u = frac * kp
        sn, cn, _ = el.ellipj_mc(jnp.float64(u), jnp.float64(mc))
        assert abs(float(sn) - np.tanh(u)) < 5e-8
        assert abs(float(cn) - 1.0 / np.cosh(u)) < 5e-8


def test_pythagorean_identity():
    for l in (1e-8, 1e-4, 0.3, 0.9):
        mc = l * l
        kp = float(el.ellipk_mc(jnp.float64(mc)))
        u = jnp.linspace(0.05, 0.95, 7) * kp
        sn, cn, dn = el.ellipj_mc(u, jnp.float64(mc))
        np.testing.assert_allclose(np.asarray(sn) ** 2 + np.asarray(cn) ** 2,
                                   1.0, atol=1e-12)
