"""repro.spectral: top-k plans, strategies, accuracy and retrace contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_matrix
from _propcheck import given, settings, st

import repro.spectral as spectral
from repro.spectral import (
    TopKConfig,
    bisect_shift,
    count_above,
    needed_power_iters,
    plan_topk,
    randomized_range,
    sketch_flops,
    srht_sketch,
    topk_residual,
    trace_count,
)
from repro.solver import SvdConfig


def _dense_ref(a, k):
    s = np.linalg.svd(np.asarray(a), compute_uv=False)
    return s[:k]


def _rankdef_matrix(m, n, kappa, rank, seed=0):
    a = np.asarray(make_matrix(m, n, kappa, seed=seed))
    u, s, vh = np.linalg.svd(a, full_matrices=False)
    s[rank:] = 0.0
    return jnp.asarray(u @ np.diag(s) @ vh)


# --- config / plan surface ----------------------------------------------


def test_topk_config_frozen_hashable():
    c1 = TopKConfig(k=8, kappa=1e6)
    c2 = TopKConfig(k=8, kappa=1e6)
    assert c1 == c2 and hash(c1) == hash(c2)
    assert c1.replace(k=4).k == 4 and c1.k == 8
    with pytest.raises(Exception):
        c1.k = 3


def test_topk_config_validation():
    with pytest.raises(ValueError):
        TopKConfig(k=0)
    with pytest.raises(ValueError):
        TopKConfig(strategy="nope")
    with pytest.raises(ValueError):
        TopKConfig(sketch_kind="nope")
    with pytest.raises(TypeError):
        TopKConfig(svd="auto")


def test_plan_topk_validation():
    with pytest.raises(TypeError):
        plan_topk("not-a-config", (64, 32))
    with pytest.raises(ValueError):
        plan_topk(TopKConfig(k=8), (64, 32, 2))
    with pytest.raises(ValueError):
        plan_topk(TopKConfig(k=64), (128, 32))  # k > min(shape)


def test_plan_topk_caching_same_object():
    cfg = TopKConfig(k=4, kappa=1e4)
    p1 = plan_topk(cfg, (96, 48))
    p2 = plan_topk(TopKConfig(k=4, kappa=1e4), (96, 48))
    assert p1 is p2
    assert plan_topk(cfg, (96, 64)) is not p1  # per-shape


def test_plan_shape_dtype_checks():
    p = plan_topk(TopKConfig(k=4, kappa=1e4), (96, 48))
    with pytest.raises(ValueError, match="per-shape"):
        p.topk(jnp.zeros((96, 64)))
    with pytest.raises(ValueError, match="dtype"):
        p.topk(jnp.zeros((96, 48), jnp.float32))


# --- strategy selection (the cost-model contract) -----------------------


def test_auto_picks_sketch_for_small_k():
    p = plan_topk(TopKConfig(k=8, kappa=1e6), (2048, 512))
    assert p.strategy == "sketch"
    assert p.decision["sketch_feasible"]
    assert p.decision["sketch_flops"] < p.decision["dense_flops"]


def test_auto_picks_dense_for_k_near_n():
    p = plan_topk(TopKConfig(k=500, kappa=1e6), (2048, 512))
    assert p.strategy == "dense"
    # l = nmin is no width reduction: the gate, not the flop count,
    # hands this to dense
    assert p.l == 512 and not p.decision["sketch_feasible"]


def test_auto_falls_back_to_dense_on_flat_spectrum():
    # kappa ~ 1: no decay for power iterations to amplify; the accuracy
    # model must refuse the sketch regardless of its flop advantage
    p = plan_topk(TopKConfig(k=8, kappa=1.0), (2048, 512))
    assert p.strategy == "dense"
    assert not p.decision["sketch_feasible"]


def test_explicit_strategy_respected():
    for strategy in ("dense", "sketch", "dnc"):
        p = plan_topk(TopKConfig(k=4, strategy=strategy, kappa=1e4),
                      (128, 64))
        assert p.strategy == strategy
        assert p.decision["requested"] == strategy


def test_flops_estimate_exposed():
    p = plan_topk(TopKConfig(k=8, kappa=1e6), (2048, 512))
    assert p.flops_estimate == p.decision[f"{p.strategy}_flops"]
    assert p.flops_estimate > 0


def test_needed_power_iters_model():
    # exhaustive sketch is exact with zero iterations
    assert needed_power_iters(64, 8, 64, 1e6, 1e-10) == 0
    # no decay -> unreachable
    assert needed_power_iters(512, 8, 16, 1.0, 1e-10) is None
    # more decay -> fewer iterations; wider sketch -> fewer iterations
    q_hi = needed_power_iters(512, 8, 40, 1e10, 1e-10)
    q_lo = needed_power_iters(512, 8, 40, 1e4, 1e-10)
    assert q_hi <= q_lo
    assert needed_power_iters(512, 8, 64, 1e4, 1e-10) <= q_lo


# --- accuracy: top-k matches the dense leading-k spectrum ---------------


def test_acceptance_topk_matches_dense_4096x512():
    """The PR acceptance case: k=16 at (4096, 512) matches dense to
    1e-10 in f64."""
    a = make_matrix(4096, 512, 1e6, seed=11)
    p = plan_topk(TopKConfig(k=16), (4096, 512))
    _, s, _ = p.topk(a)
    ref = _dense_ref(a, 16)
    assert np.max(np.abs(np.asarray(s) - ref)) <= 1e-10 * ref[0]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2), st.integers(0, 2), st.integers(1, 24))
def test_property_topk_matches_dense(shape_idx, kappa_idx, k):
    """Across tall/wide/square and kappa in {1e2, 1e6, 1e10}: the top-k
    values match the dense leading k to 1e-10 (f64), including k at and
    beyond the numerical rank."""
    shapes = [(384, 96), (96, 384), (192, 192)]
    kappas = [1e2, 1e6, 1e10]
    m, n = shapes[int(shape_idx)]
    kappa = kappas[int(kappa_idx)]
    k = min(int(k), min(m, n))
    a = make_matrix(m, n, kappa, seed=7 + int(shape_idx))
    p = plan_topk(TopKConfig(k=k, kappa=kappa), (m, n))
    u, s, vh = p.topk(a)
    ref = _dense_ref(a, k)
    assert np.max(np.abs(np.asarray(s) - ref)) <= 1e-10 * ref[0]
    assert u.shape == (m, k) and s.shape == (k,) and vh.shape == (k, n)
    # triplets are consistent: the backward residual obeys the subspace
    # bound rho^(2q+1) ~ sqrt(value tol) (values converge quadratically,
    # subspaces linearly — the same gap topk_adaptive's gate encodes)
    res = float(topk_residual(a, u, s, vh))
    assert res <= 1e-5


def test_topk_beyond_rank():
    """k greater than the true rank: trailing values are exactly the
    dense (zero) tail, leading values exact."""
    a = _rankdef_matrix(256, 64, 1e4, rank=10, seed=3)
    p = plan_topk(TopKConfig(k=24, kappa=1e4), (256, 64))
    _, s, _ = p.topk(a)
    ref = _dense_ref(a, 24)
    assert np.max(np.abs(np.asarray(s) - ref)) <= 1e-10 * ref[0]
    assert np.all(np.asarray(s)[10:] <= 1e-10 * ref[0])


def test_sketch_strategy_accuracy_explicit():
    a = make_matrix(1024, 256, 1e6, seed=5)
    p = plan_topk(TopKConfig(k=16, kappa=1e6), (1024, 256))
    assert p.strategy == "sketch"  # regression: this regime must sketch
    _, s, _ = p.topk(a)
    ref = _dense_ref(a, 16)
    assert np.max(np.abs(np.asarray(s) - ref)) <= 1e-10 * ref[0]


def test_srht_sketch_kind():
    a = make_matrix(512, 96, 1e6, seed=6)
    p = plan_topk(TopKConfig(k=8, kappa=1e6, sketch_kind="srht",
                             strategy="sketch"), (512, 96))
    _, s, _ = p.topk(a)
    ref = _dense_ref(a, 8)
    assert np.max(np.abs(np.asarray(s) - ref)) <= 1e-10 * ref[0]


def test_batched_topk():
    mats = jnp.stack([make_matrix(128, 48, 1e4, seed=s)
                      for s in (1, 2, 3)])
    p = plan_topk(TopKConfig(k=6, kappa=1e4), (128, 48))
    u, s, vh = p.topk_batched(mats)
    assert u.shape == (3, 128, 6) and s.shape == (3, 6)
    assert vh.shape == (3, 6, 48)
    for i in range(3):
        ref = _dense_ref(mats[i], 6)
        assert np.max(np.abs(np.asarray(s[i]) - ref)) <= 1e-10 * ref[0]


# --- d&c strategy --------------------------------------------------------


def test_dnc_topk_matches_dense():
    a = make_matrix(256, 96, 1e3, seed=8)
    p = plan_topk(TopKConfig(k=8, strategy="dnc", kappa=1e3), (256, 96))
    u, s, vh, info = p.topk_with_info(a)
    ref = _dense_ref(a, 8)
    assert np.max(np.abs(np.asarray(s) - ref)) <= 1e-10 * ref[0]
    assert bool(info["converged"])
    cnt = float(info["count"])
    assert p.k <= cnt <= p.l


def test_dnc_wide_input():
    a = make_matrix(96, 256, 1e3, seed=9)
    p = plan_topk(TopKConfig(k=8, strategy="dnc", kappa=1e3), (96, 256))
    u, s, vh = p.topk(a)
    ref = _dense_ref(a, 8)
    assert np.max(np.abs(np.asarray(s) - ref)) <= 1e-10 * ref[0]
    assert u.shape == (96, 8) and vh.shape == (8, 256)


def test_count_above_on_known_spectrum():
    # diag matrix: sign factor is known in closed form
    w = jnp.asarray([3.0, 2.0, 1.0, 0.5, 0.1])
    q = jnp.diag(jnp.sign(w - 0.75))
    assert float(count_above(q)) == 3.0


def test_bisect_shift_diag():
    """Bisection on an explicitly diagonal Gram: exact sign oracle."""
    w = jnp.geomspace(1.0, 1e-6, 32)
    c = jnp.diag(w)

    def sign_fn(x):
        return jnp.diag(jnp.sign(jnp.diag(x)))

    lo2, hi2 = jnp.asarray(1e-6), jnp.asarray(1.0 + 1e-12)
    q, s, cnt, converged, rounds = bisect_shift(
        c, 4, 8, sign_fn, lo2, hi2, max_rounds=24)
    assert bool(converged)
    assert 4 <= float(cnt) <= 8


# --- compile-once / zero-retrace contract -------------------------------


def test_zero_retraces_on_repeat():
    a = make_matrix(256, 64, 1e4, seed=10)
    p = plan_topk(TopKConfig(k=4, kappa=1e4), (256, 64))
    p.topk(a)  # compile
    before = trace_count()
    for _ in range(3):
        p.topk(a)
    p.topk(a + 0.1 * make_matrix(256, 64, 1e2, seed=12))  # new values
    assert trace_count() == before


def test_zero_retraces_across_strategies():
    a = make_matrix(128, 64, 1e3, seed=13)
    for strategy in ("dense", "sketch", "dnc"):
        p = plan_topk(TopKConfig(k=4, strategy=strategy, kappa=1e3),
                      (128, 64))
        p.topk(a)
        before = trace_count()
        p.topk(a)
        assert trace_count() == before, strategy


# --- adaptive escalation -------------------------------------------------


def test_topk_adaptive_no_escalation_when_accurate():
    a = make_matrix(512, 128, 1e6, seed=14)
    p = plan_topk(TopKConfig(k=8, kappa=1e6), (512, 128))
    assert p.strategy == "sketch"
    _, s, _, info = p.topk_adaptive(a)
    assert info["escalated"] is False
    assert info["residual"] is not None and info["residual"] < 1e-5


def test_topk_adaptive_escalates_underpowered_sketch():
    # an explicitly under-powered sketch (0 iterations, thin window) on
    # a slowly-decaying spectrum misses tol; escalation must recover
    # the dense answer
    a = make_matrix(384, 128, 1e2, seed=15)
    p = plan_topk(TopKConfig(k=8, oversample=2, power_iters=0,
                             strategy="sketch", kappa=1e2, tol=1e-10),
                  (384, 128))
    _, s, _, info = p.topk_adaptive(a, tol=1e-9)
    assert info["escalated"] is True
    ref = _dense_ref(a, 8)
    assert np.max(np.abs(np.asarray(s) - ref)) <= 1e-10 * ref[0]


# --- building blocks -----------------------------------------------------


def test_randomized_range_spans_leading_subspace():
    a = make_matrix(256, 64, 1e8, seed=16)
    q = randomized_range(a, 16, 4, jax.random.PRNGKey(0))
    assert q.shape == (256, 16)
    # orthonormal
    g = np.asarray(q).T @ np.asarray(q)
    assert np.linalg.norm(g - np.eye(16)) < 1e-12
    # captures the leading left vectors: projection residual of u_1..u_4
    u = np.linalg.svd(np.asarray(a))[0][:, :4]
    proj = np.asarray(q) @ (np.asarray(q).T @ u)
    assert np.linalg.norm(proj - u) < 1e-10


def test_srht_sketch_shapes_and_determinism():
    a = make_matrix(64, 48, 1e2, seed=17)
    y1 = srht_sketch(a, 12, jax.random.PRNGKey(3))
    y2 = srht_sketch(a, 12, jax.random.PRNGKey(3))
    assert y1.shape == (64, 12)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_sketch_flops_monotone():
    base = sketch_flops(4096, 512, 16, 32, 2, small_flops=1e6)
    assert sketch_flops(4096, 512, 16, 32, 4, small_flops=1e6) > base
    assert sketch_flops(4096, 512, 16, 64, 2, small_flops=1e6) > base


def test_inner_plans_share_solver_cost_basis():
    """The dense strategy's price is exactly repro.solver.flops_estimate
    — one cost-model contract across both planners."""
    from repro.solver import flops_estimate

    p = plan_topk(TopKConfig(k=8, kappa=1e6), (2048, 512))
    inner = p._inner["dense"]
    assert p.decision["dense_flops"] == flops_estimate(
        inner.config, (2048, 512), inner.dtype)


def test_topk_cache_stats_counts():
    spectral.clear_topk_cache()
    stats0 = spectral.topk_cache_stats()
    cfg = TopKConfig(k=3, kappa=1e4)
    plan_topk(cfg, (64, 32))
    plan_topk(cfg, (64, 32))
    stats1 = spectral.topk_cache_stats()
    assert stats1["plan_misses"] == stats0["plan_misses"] + 1
    assert stats1["plan_hits"] >= stats0["plan_hits"] + 1
