"""SVD drivers vs jnp.linalg.svd; paper Fig. 2 accuracy levels."""

import numpy as np
import pytest

import jax.numpy as jnp
import repro.core as C

from conftest import make_matrix


@pytest.mark.parametrize("kappa", [1.29, 14.0, 9.06e3, 3.16e8, 3.46e11])
def test_zolo_svd_accuracy(kappa):
    """Paper Fig. 2: residual and orthogonality at machine-precision level
    for the UF-matrix condition numbers."""
    a = make_matrix(96, 96, kappa, seed=int(np.log10(kappa) * 7) + 1)
    u, s, vh = C.polar_svd(a, method="zolo", r=2)
    assert float(C.svd_residual(a, u, s, vh)) < 5e-13
    assert float(C.orthogonality(u)) < 1e-14 * a.shape[0]
    assert float(C.orthogonality(vh.T)) < 1e-14 * a.shape[0]
    s0 = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-13)


def test_qdwh_svd_matches():
    a = make_matrix(80, 80, 1e7, seed=3)
    u, s, vh = C.polar_svd(a, method="qdwh")
    s0 = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-13)
    assert float(C.svd_residual(a, u, s, vh)) < 5e-13


def test_rectangular_both_orientations():
    for (m, n) in [(120, 72), (72, 120)]:
        a = make_matrix(m, n, 50.0, seed=m)
        u, s, vh = C.polar_svd(a, method="zolo", r=2)
        assert u.shape == (m, min(m, n))
        assert vh.shape == (min(m, n), n)
        rec = u * s[None, :] @ vh
        assert float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a)) < 1e-12


def test_block_jacobi_eigh():
    h = np.asarray(make_matrix(96, 96, 1e3, seed=6))
    h = h + h.T
    w, v = C.padded_block_jacobi_eigh(jnp.asarray(h), nb=16)
    w0 = np.linalg.eigvalsh(h)
    np.testing.assert_allclose(np.asarray(w), w0, atol=1e-12)
    assert float(C.orthogonality(v)) < 1e-14


def test_block_jacobi_eigh_padded_sizes():
    # n = 90 forces both block padding and even-block-count padding
    h = np.asarray(make_matrix(90, 90, 10.0, seed=2))
    h = h + h.T
    w, v = C.padded_block_jacobi_eigh(jnp.asarray(h), nb=16)
    w0 = np.linalg.eigvalsh(h)
    np.testing.assert_allclose(np.asarray(w), w0, atol=1e-11)


def test_polar_svd_with_jacobi_eig():
    a = make_matrix(64, 64, 100.0, seed=12)
    u, s, vh = C.polar_svd(a, method="zolo", eig_method="jacobi", nb=16)
    s0 = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-12)


def test_jacobi_svd_shape_validation():
    """Misuse raises ValueError with the offending shapes (not a bare
    assert, so it still fails under python -O)."""
    a = make_matrix(32, 24, 10.0, seed=4)
    with pytest.raises(ValueError, match=r"nb=10"):
        C.jacobi_svd(a, nb=10)  # 24 % 10 != 0
    with pytest.raises(ValueError, match=r"even block count"):
        C.jacobi_svd(a, nb=8)  # 24 // 8 == 3 blocks: odd
    with pytest.raises(ValueError, match="one"):
        C.jacobi_svd(jnp.zeros((2, 16, 16)), nb=8)


def test_jacobi_svd_baseline():
    a = make_matrix(100, 64, 50.0, seed=1)
    u, s, vh = C.jacobi_svd(a, nb=16)
    s0 = np.linalg.svd(np.asarray(a), compute_uv=False)
    np.testing.assert_allclose(np.asarray(s), s0, atol=1e-12)
    assert float(C.svd_residual(a, u, s, vh)) < 1e-12
