"""Partial-spectrum throughput: top-k through ``repro.spectral`` vs the
dense solve-then-slice baseline.

One row per (n, k) cell: the auto-planned top-k path (cost model picks
sketch vs dense; the committed record's cells all resolve to sketch) is
timed against an explicit ``strategy="dense"`` plan of the same
``TopKConfig`` — the honest baseline, since a dense plan *is* how you
would get the leading k triplets without the subsystem.  Emits the
measured speedup, the cost model's predicted flop ratio next to it, and
the max leading-value error of the fast path against the dense one.

Writes the machine-readable ``BENCH_topk.json`` record.  The committed
copy is generated at n >= 2048 and k <= n/8, where the sketch path must
win; CPU wall-clock proves the ordering, a TPU run of this same file
regenerates honest absolute numbers.

  PYTHONPATH=src python -m benchmarks.run --only svd_topk
"""

from __future__ import annotations

import json
import os

os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np  # noqa: E402

from benchmarks.common import (  # noqa: E402
    REPEATS,
    emit,
    make_matrix,
    time_fn,
)

BENCH_JSON = os.environ.get("REPRO_BENCH_TOPK_JSON", "BENCH_topk.json")
TOPK_N = int(os.environ.get("REPRO_BENCH_TOPK_N", "2048"))


def _cells(n):
    """(m, n, k, kappa) sweep scaled by one size knob: the square
    k << n regime at two ranks, and the tall acceptance shape."""
    return (
        (n, n, max(4, n // 16), 1e10),
        (n, n, max(8, n // 8), 1e10),
        (2 * n, n // 4, max(8, n // 32), 1e6),
    )


def run():
    import jax.numpy as jnp

    from repro.spectral import TopKConfig, plan_topk

    records = []
    for m, n, k, kappa in _cells(TOPK_N):
        a = make_matrix(n, kappa, m=m, seed=0, dtype=jnp.float64)
        cfg = TopKConfig(k=k, kappa=kappa)
        p_auto = plan_topk(cfg, (m, n), jnp.float64)
        p_dense = plan_topk(cfg.replace(strategy="dense"), (m, n),
                            jnp.float64)
        t_auto = time_fn(p_auto.topk, a)
        t_dense = time_fn(p_dense.topk, a)
        s_fast = np.asarray(p_auto.topk(a)[1])
        s_ref = np.asarray(p_dense.topk(a)[1])
        err = float(np.abs(s_fast - s_ref).max() / s_ref[0])
        d = p_auto.decision
        flop_ratio = (d["sketch_flops"] / d["dense_flops"]
                      if d.get("sketch_flops") else float("nan"))
        rec = {
            "m": m, "n": n, "k": k, "kappa": kappa,
            "strategy": p_auto.strategy,
            "l": p_auto.l, "q_iters": p_auto.q_iters,
            "t_topk_s": t_auto, "t_dense_s": t_dense,
            "speedup": t_dense / t_auto,
            "flop_ratio_model": flop_ratio,
            "max_value_err": err,
        }
        records.append(rec)
        emit(f"topk.{m}x{n}.k{k}", t_auto * 1e6,
             f"{p_auto.strategy} l={p_auto.l} q={p_auto.q_iters} "
             f"speedup={rec['speedup']:.2f}x "
             f"model={flop_ratio:.3f} err={err:.1e}")

    with open(BENCH_JSON, "w") as f:
        json.dump({
            "bench": "svd_topk",
            "repeats": REPEATS,
            "device": "cpu",
            "note": "auto-planned top-k vs dense solve-then-slice of the "
                    "same TopKConfig; CPU rows prove the ordering — "
                    "regenerate on TPU for honest wall-clock",
            "records": records,
        }, f, indent=1)
    emit("topk.json_record", 0.0, BENCH_JSON)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
