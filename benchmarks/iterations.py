"""Paper Table 1 (theory) and Tables 5/10 (measured iteration counts)."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp
import repro.core as C
from repro.core import coeffs as CF

from benchmarks.common import BENCH_N, emit, make_matrix, time_fn

KAPPAS_T1 = [1.001, 1.01, 1.1, 1.2, 1.5, 2, 10, 1e2, 1e3, 1e5, 1e7, 1e16]
PAPER_T1 = {
    1: [2, 2, 2, 3, 3, 3, 4, 4, 4, 5, 5, 6],
    2: [1, 2, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4],
    3: [1, 1, 2, 2, 2, 2, 2, 2, 3, 3, 3, 3],
    4: [1, 1, 1, 2, 2, 2, 2, 2, 2, 3, 3, 3],
    5: [1, 1, 1, 1, 2, 2, 2, 2, 2, 2, 3, 3],
    6: [1, 1, 1, 1, 1, 2, 2, 2, 2, 2, 2, 3],
    7: [1, 1, 1, 1, 1, 1, 2, 2, 2, 2, 2, 3],
    8: [1, 1, 1, 1, 1, 1, 2, 2, 2, 2, 2, 2],
}

# paper Table 5 (measured) for the Example-1 matrices, and Table 10 rows
PAPER_T5 = {"nemeth03": (1.29, {2: 3, 3: 3, 4: 3}),
            "fv1": (1.40e1, {2: 4, 3: 3, 4: 3}),
            "linverse": (9.06e3, {2: 4, 3: 3, 4: 3})}
PAPER_T10 = {"bcsstk18": (3.46e11, {2: 4, 3: 4, 4: 3, 5: 3}),
             "c-47": (3.16e8, {2: 4, 3: 4, 4: 3, 5: 3}),
             "rand1": (3.97e7, {2: 4, 3: 4, 4: 3, 5: 3})}


def table1():
    """Regenerate Table 1 from the scalar Zolotarev recursion."""
    mismatch = 0
    for r, row in PAPER_T1.items():
        ours = [CF.zolo_iter_count(k, r) for k in KAPPAS_T1]
        mismatch += sum(1 for a, b in zip(ours, row) if a != b)
    emit("table1.cells_matching_paper", 0.0, f"{96 - mismatch}/96")
    # the one borderline cell (r=7, kappa=2) achieves 1.22e-15 vs the
    # 1e-15 band; it matches at tol 1.3e-15
    emit("table1.cells_matching_at_1.3e-15", 0.0,
         f"{sum(1 for r, row in PAPER_T1.items() for k, b in zip(KAPPAS_T1, row) if CF.zolo_iter_count(k, r, tol=1.3e-15) == b)}/96")
    emit("table1.qdwh_iters_kappa_1e16", 0.0, str(CF.qdwh_iter_count(1e16)))


def tables5_10():
    """Measured matrix iteration counts vs the paper's measured tables."""
    n = min(BENCH_N, 512)
    for table, entries in (("table5", PAPER_T5), ("table10", PAPER_T10)):
        agree = total = 0
        for name, (kappa, by_r) in entries.items():
            a = make_matrix(n, kappa, m=n, seed=3)
            for r, paper_iters in by_r.items():
                _, _, info = C.zolo_pd(a, r=r, alpha=1.0, l=0.9 / kappa,
                                       want_h=False)
                ours = int(info.iterations)
                total += 1
                agree += int(abs(ours - paper_iters) <= 1)
                emit(f"{table}.{name}.r{r}.iters", 0.0,
                     f"ours={ours};paper={paper_iters}")
        emit(f"{table}.within_one_of_paper", 0.0, f"{agree}/{total}")


def run():
    table1()
    tables5_10()
