"""Paper Table 2: structured QR (MPDGEQRF/MPDORGQR) vs dense stacked QR.

Two readings:
* flop model at the paper's sizes (10000x5000, 20000x10000) — the
  structural saving the paper measures as 1.18-1.51x;
* CPU wall-clock at reduced sizes — honest caveat: our structured QR is
  generic XLA loop code while jnp.linalg.qr calls tuned LAPACK, so CPU
  wall-clock understates the structural advantage (on TPU both paths are
  XLA).  The flop ratio is the hardware-transferable number.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

import repro.core.structured_qr  # noqa: F401
SQ = sys.modules["repro.core.structured_qr"]

from benchmarks.common import BENCH_N, emit, make_matrix, time_fn
from repro.configs.svd_paper import QR_SHAPES


def run():
    for (m, n) in QR_SHAPES:
        f = SQ.structured_qr_flops(m, n, 64)
        emit(f"table2.flops.{m}x{n}.geqrf_speedup", 0.0,
             f"{f['speedup_geqrf']:.2f}x (paper 1.18-1.36x)")
        emit(f"table2.flops.{m}x{n}.orgqr_speedup", 0.0,
             f"{f['speedup_orgqr']:.2f}x (paper 1.21-1.51x)")

    # CPU wall-clock at reduced size
    m, n = 2 * BENCH_N, BENCH_N
    x = make_matrix(n, 10.0, m=m, seed=1)
    sqc = jnp.float64(0.5)

    dense = jax.jit(lambda x_: SQ.dense_stacked_qr_q1q2(x_, sqc))
    struct = jax.jit(lambda x_: SQ.structured_qr_q1q2(x_, sqc, block=64))
    t_dense = time_fn(dense, x)
    t_struct = time_fn(struct, x)
    emit(f"table2.cpu.{m}x{n}.dense_qr", t_dense * 1e6, "")
    emit(f"table2.cpu.{m}x{n}.structured_qr", t_struct * 1e6,
         f"speedup={t_dense / t_struct:.2f}x (LAPACK-vs-XLA caveat)")
