"""Shared benchmark utilities: timing, matrix synthesis, CSV output."""

from __future__ import annotations

import os
import time

import numpy as np

import jax
import jax.numpy as jnp

# Bench matrix size knob (CPU wall-clock runs); full paper sizes are used
# for flop models only.
BENCH_N = int(os.environ.get("REPRO_BENCH_N", "768"))
REPEATS = int(os.environ.get("REPRO_BENCH_REPEATS", "3"))

_rows: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def all_rows():
    return list(_rows)


def time_fn(fn, *args, repeats: int = None, warmup: int = 1):
    """Median wall-clock seconds of fn(*args) (blocks on jax arrays)."""
    repeats = repeats or REPEATS
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def make_matrix(n: int, kappa: float, m: int = None, seed: int = 0,
                dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    m = m or n
    k = min(m, n)
    u, _ = np.linalg.qr(rng.standard_normal((m, k)))
    v, _ = np.linalg.qr(rng.standard_normal((n, k)))
    s = np.geomspace(1.0, 1.0 / kappa, k)
    return jnp.asarray((u * s) @ v.T, dtype=dtype)


def kernel_vs_xla_polar(a, *, l0, r=2, compute_dtype=None):
    """Time the kernel-backed (zolo_pallas) vs XLA (zolo_static) polar
    solve of the pre-scaled matrix ``a`` through ``repro.solver`` plans.

    One comparison protocol for every suite (kernels, pd_compare):
    returns (t_xla_s, t_ker_s, max_abs_err, kernel_plan).
    ``compute_dtype`` threads the config's precision override into both
    plans (the bf16-envelope rows of the kernels suite).
    """
    import jax.numpy as jnp

    import repro.solver as S

    cfg_kw = dict(l0=l0, r=r, scale="none", compute_dtype=compute_dtype)
    p_xla = S.plan(S.SvdConfig(method="zolo_static", **cfg_kw),
                   a.shape, a.dtype)
    p_ker = S.plan(S.SvdConfig(method="zolo_pallas", **cfg_kw),
                   a.shape, a.dtype)
    t_xla = time_fn(lambda x: p_xla.polar(x, want_h=False)[0], a)
    t_ker = time_fn(lambda x: p_ker.polar(x, want_h=False)[0], a)
    err = float(jnp.abs(p_ker.polar(a, want_h=False)[0]
                        - p_xla.polar(a, want_h=False)[0]).max())
    return t_xla, t_ker, err, p_ker
