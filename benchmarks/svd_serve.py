"""Serving throughput: solves/s and latency percentiles of the SVD
service under an open-loop heterogeneous stream.

The solver-side analog of a decode tokens/s microbenchmark: each row is
one (batch_size, arrival_rate) cell of the sweep, driven end-to-end
through :func:`repro.launch.svd_serve.run_workload` — Poisson arrivals
over a mixed shape pool (tall, wide, two accuracy modes), bucketed into
the padded plan pool, continuously micro-batched, async-dispatched.
Writes the machine-readable ``BENCH_serve.json`` record: solves/s,
p50/p99 latency, pad-waste fraction, plan-cache hit rate per cell (the
hit rate is 1.0 and retraces 0 in every cell — the warmed steady state
the service tests assert).

CPU rows prove the serving machinery and its overheads; a TPU run of
this same file regenerates honest wall-clock.

  PYTHONPATH=src python -m benchmarks.run --only svd_serve
"""

from __future__ import annotations

import json
import os

from benchmarks.common import emit

BENCH_JSON = os.environ.get("REPRO_BENCH_SERVE_JSON", "BENCH_serve.json")
REQUESTS = int(os.environ.get("REPRO_BENCH_SERVE_REQUESTS", "48"))
BATCH_SIZES = (2, 4, 8)
RATES = (100.0, 400.0)
SHAPES = ((96, 64), (120, 80), (64, 48), (40, 100))
MODES = ("fast", "standard")


def run():
    import jax.numpy as jnp

    from repro.launch.svd_serve import run_workload
    from repro.serve import ServiceConfig, SvdService

    records = []
    for batch in BATCH_SIZES:
        for rate in RATES:
            service = SvdService(ServiceConfig(batch_size=batch,
                                               max_wait=0.005))
            rec = run_workload(service, SHAPES, modes=MODES,
                               requests=REQUESTS, rate=rate,
                               kappa=1e3, dtype=jnp.float64, seed=0)
            rec["batch_size"] = batch
            records.append(rec)
            emit(f"serve.b{batch}.rate{rate:.0f}",
                 1e6 / rec["solves_per_s"],
                 f"{rec['solves_per_s']:.1f}/s "
                 f"p50={rec['p50_ms']:.1f}ms p99={rec['p99_ms']:.1f}ms "
                 f"waste={rec['pad_waste']:.2f} "
                 f"hit={rec['plan_cache_hit_rate']:.2f}")

    with open(BENCH_JSON, "w") as f:
        json.dump({
            "bench": "svd_serve",
            "requests_per_cell": REQUESTS,
            "shape_pool": [list(s) for s in SHAPES],
            "mode_pool": list(MODES),
            "device": "cpu",
            "note": "open-loop Poisson stream; CPU rows prove the "
                    "serving machinery — regenerate on TPU for honest "
                    "wall-clock",
            "records": records,
        }, f, indent=1)
    emit("serve.json_record", 0.0, BENCH_JSON)


if __name__ == "__main__":
    os.environ.setdefault("JAX_ENABLE_X64", "1")
    print("name,us_per_call,derived")
    run()
