"""Serving throughput: solves/s and latency percentiles of the SVD
service under an open-loop heterogeneous stream.

The solver-side analog of a decode tokens/s microbenchmark: each row is
one (batch_size, arrival_rate) cell of the sweep, driven end-to-end
through :func:`repro.launch.svd_serve.run_workload` — Poisson arrivals
over a mixed shape pool (tall, wide, two accuracy modes), bucketed into
the padded plan pool, continuously micro-batched, async-dispatched.
Writes the machine-readable ``BENCH_serve.json`` record: solves/s,
p50/p99 latency, pad-waste fraction, plan-cache hit rate per cell (the
hit rate is 1.0 and retraces 0 in every cell — the warmed steady state
the service tests assert).

Two PR 9 sections ride along:

* ``verify_overhead`` — the same burst workload with ``verify`` off
  vs on: the in-graph health check's cost in solves/s
  (``overhead_frac``; the acceptance bar is < 5%).
* ``fault_axis`` — solves/s vs injected NaN fault rate
  (``ServiceFaults.nan_request_seqs``): each faulted request fails its
  rung-0 health check and recovers up the escalation ladder, and the
  record shows retries == faults with zero quarantines.

CPU rows prove the serving machinery and its overheads; a TPU run of
this same file regenerates honest wall-clock.

  PYTHONPATH=src python -m benchmarks.run --only svd_serve
"""

from __future__ import annotations

import json
import os
from dataclasses import replace as dataclass_replace

from benchmarks.common import emit

BENCH_JSON = os.environ.get("REPRO_BENCH_SERVE_JSON", "BENCH_serve.json")
REQUESTS = int(os.environ.get("REPRO_BENCH_SERVE_REQUESTS", "48"))
BATCH_SIZES = (2, 4, 8)
RATES = (100.0, 400.0)
SHAPES = ((96, 64), (120, 80), (64, 48), (40, 100))
MODES = ("fast", "standard")
# the stream stays inside every mode's accuracy contract (true kappa
# <= the "fast" hint of 1e2): out-of-contract requests legitimately
# fail their eps-level health check and escalate, which would measure
# ladder retries, not the in-graph check this record prices
KAPPA = 1e2
# throughput-bound arrival rate for the overhead/fault sections: every
# request arrives at t=0, so solves/s measures the service, not the
# Poisson clock
BURST_RATE = 1e6
FAULT_RATES = (0.125, 0.25)


def run():
    import jax.numpy as jnp

    from repro.launch.svd_serve import run_workload
    from repro.serve import ServiceConfig, ServiceFaults, SvdService

    records = []
    for batch in BATCH_SIZES:
        for rate in RATES:
            service = SvdService(ServiceConfig(batch_size=batch,
                                               max_wait=0.005))
            rec = run_workload(service, SHAPES, modes=MODES,
                               requests=REQUESTS, rate=rate,
                               kappa=KAPPA, dtype=jnp.float64, seed=0)
            rec["batch_size"] = batch
            records.append(rec)
            emit(f"serve.b{batch}.rate{rate:.0f}",
                 1e6 / rec["solves_per_s"],
                 f"{rec['solves_per_s']:.1f}/s "
                 f"p50={rec['p50_ms']:.1f}ms p99={rec['p99_ms']:.1f}ms "
                 f"waste={rec['pad_waste']:.2f} "
                 f"hit={rec['plan_cache_hit_rate']:.2f}")

    # --- verification overhead: verified vs unverified solves/s -------
    def burst(config, fault_rate=0.0, repeats=3, requests=REQUESTS):
        # best-of-N: the first repeat eats one-time executable compiles
        # (retry lanes only exist after the first injected fault) and a
        # single ~0.3 s burst is noise-bound on CPU; the max is the
        # steady-state rate the overhead comparison needs
        best = None
        for _ in range(repeats):
            rec = run_workload(SvdService(config), SHAPES, modes=MODES,
                               requests=requests, rate=BURST_RATE,
                               kappa=KAPPA, dtype=jnp.float64, seed=0)
            if best is None or rec["solves_per_s"] > best["solves_per_s"]:
                best = rec
        best["fault_rate"] = fault_rate
        best["batch_size"] = config.batch_size
        return best

    # a longer stream for the A/B pair (resolving a few-percent delta
    # needs more than a quarter-second of wall-clock per side), scored
    # as the median of per-round paired ratios with the order
    # alternating between rounds: pairing cancels slow machine drift
    # across the benchmark's minutes of sustained load, alternation
    # cancels first-vs-second position bias within a round (allocator
    # and cache state left by one burst taxes whichever runs next)
    base = ServiceConfig(batch_size=4, max_wait=0.005)
    plain = checked = None
    ratios = []
    for round_i in range(6):
        cfgs = [(False, dataclass_replace(base, verify=False)),
                (True, base)]
        if round_i % 2:
            cfgs.reverse()
        rate_of = {}
        for is_verified, cfg in cfgs:
            rec = burst(cfg, repeats=1, requests=4 * REQUESTS)
            rate_of[is_verified] = rec
        p, c = rate_of[False], rate_of[True]
        ratios.append(c["solves_per_s"] / p["solves_per_s"])
        if plain is None or p["solves_per_s"] > plain["solves_per_s"]:
            plain = p
        if checked is None or c["solves_per_s"] > checked["solves_per_s"]:
            checked = c
    overhead = 1.0 - sorted(ratios)[len(ratios) // 2]
    verify_overhead = {
        "unverified_solves_per_s": plain["solves_per_s"],
        "verified_solves_per_s": checked["solves_per_s"],
        "paired_ratios": ratios,
        "overhead_frac": overhead,
    }
    emit("serve.verify_overhead", 0.0,
         f"unverified={plain['solves_per_s']:.1f}/s "
         f"verified={checked['solves_per_s']:.1f}/s "
         f"overhead={overhead * 100:.1f}%")

    # --- fault axis: injected NaN solves recovered up the ladder ------
    fault_records = [checked]
    for frate in FAULT_RATES:
        stride = max(1, round(1.0 / frate))
        seqs = tuple(range(0, REQUESTS, stride))
        cfg = dataclass_replace(
            base, faults=ServiceFaults(nan_request_seqs=seqs))
        rec = burst(cfg, fault_rate=len(seqs) / REQUESTS)
        fault_records.append(rec)
        emit(f"serve.faults{frate:.3f}", 1e6 / rec["solves_per_s"],
             f"{rec['solves_per_s']:.1f}/s retries={rec['retries']} "
             f"quarantined={rec['quarantined']} ok={rec['ok']}")

    with open(BENCH_JSON, "w") as f:
        json.dump({
            "bench": "svd_serve",
            "requests_per_cell": REQUESTS,
            "shape_pool": [list(s) for s in SHAPES],
            "mode_pool": list(MODES),
            "device": "cpu",
            "note": "open-loop Poisson stream; CPU rows prove the "
                    "serving machinery — regenerate on TPU for honest "
                    "wall-clock",
            "records": records,
            "verify_overhead": verify_overhead,
            "fault_axis": fault_records,
        }, f, indent=1)
    emit("serve.json_record", 0.0, BENCH_JSON)


if __name__ == "__main__":
    os.environ.setdefault("JAX_ENABLE_X64", "1")
    print("name,us_per_call,derived")
    run()
