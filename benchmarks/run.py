"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Wall-clock rows are CPU
medians (the container has no TPU); structural rows (iteration counts,
flop models, accuracy, roofline terms from the dry-run) are the
hardware-transferable results.  See EXPERIMENTS.md for interpretation.

  PYTHONPATH=src python -m benchmarks.run [--only iterations,fig2,...]
"""

from __future__ import annotations

import argparse
import os
import sys

os.environ.setdefault("JAX_ENABLE_X64", "1")

from benchmarks import (  # noqa: E402
    accuracy,
    comm_calibrate,
    grouped_scaling,
    iterations,
    kernels_bench,
    pd_compare,
    pd_profile,
    roofline,
    structured_qr_bench,
    svd_compare,
    svd_serve,
    svd_topk,
)

SUITES = {
    "iterations": iterations.run,       # paper Tables 1, 5, 10
    "structured_qr": structured_qr_bench.run,  # paper Table 2
    "svd_compare": svd_compare.run,     # paper Tables 4, 9
    "pd_compare": pd_compare.run,       # paper Table 6
    "pd_profile": pd_profile.run,       # paper Table 7
    "accuracy": accuracy.run,           # paper Figure 2
    "kernels": kernels_bench.run,       # Pallas kernel parity
    "grouped_scaling": grouped_scaling.run,  # Alg. 3 (r, sep) sweep
    "comm_calibrate": comm_calibrate.run,  # psum cost per word
    "svd_serve": svd_serve.run,         # serving solves/s + latency
    "svd_topk": svd_topk.run,           # partial-spectrum vs dense slice
    "roofline": roofline.run,           # §Roofline summary (from dry-run)
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(SUITES))
    print("name,us_per_call,derived")
    for name in names:
        try:
            SUITES[name]()
        except Exception as e:  # keep the harness going; report the break
            print(f"{name}.ERROR,0.0,{type(e).__name__}:{str(e)[:120]}",
                  flush=True)


if __name__ == "__main__":
    main()
