"""Paper Table 7: stage profile of Zolo-PD (QR / Chol / Combine / FormX2).

The paper profiles MPI stage times; here each stage is timed as a jitted
unit on CPU (relative shares are the transferable signal — the combine
stage being negligible is the paper's point, and it is *structurally*
negligible here too: psum bytes / factorization flops).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import coeffs as CF
from repro.core import zolo as Z
from benchmarks.common import BENCH_N, emit, make_matrix, time_fn


def run():
    n = BENCH_N
    kappa, r = 1.4e1, 3  # the paper profiles fv1 with r=3
    a = make_matrix(n, kappa, m=n, seed=7)
    c, aj, mh = CF.zolo_coeffs_np(0.9 / kappa, r)
    cj, ajj, mhj = jnp.asarray(c), jnp.asarray(aj), jnp.asarray(mh)

    qr_iter = jax.jit(lambda x: Z.zolo_iteration(x, cj[0::2], ajj, mhj,
                                                 mode="cholqr2"))
    chol_iter = jax.jit(lambda x: Z.zolo_iteration(x, cj[0::2], ajj, mhj,
                                                   mode="chol"))

    # combine/FormX2 in isolation: the weighted r-term sum
    t_stack = jnp.stack([a] * r)
    combine = jax.jit(lambda x, t: mhj * (x + jnp.einsum(
        "j,jmn->mn", ajj, t)))

    t_qr = time_fn(qr_iter, a)
    t_chol = time_fn(chol_iter, a)
    t_comb = time_fn(combine, a, t_stack)
    emit("table7.qr_iteration", t_qr * 1e6, "")
    emit("table7.chol_iteration", t_chol * 1e6, "")
    emit("table7.combine_formx2", t_comb * 1e6,
         f"share_of_chol={t_comb / t_chol:.3f} (paper: ~1e-2)")
