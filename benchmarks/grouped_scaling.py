"""Grouped (Algorithm 3) scaling sweep over (r, sep) mesh factorizations.

At a fixed device count every divisor r of ndev gives a two-level layout
ndev = r groups x sep devices: r-way term parallelism over "zolo" and
the intra-group row distribution over "sep".  This suite runs the same
polar solve through an ``SvdPlan`` on each factorization (method="auto",
so the sep-aware cost model does the picking) twice — with the plan-time
static schedule and with the runtime-conditioning dynamic backend
(``l0_policy="runtime"`` -> ``zolo_grouped_dynamic``: in-graph
sep-collective sigma_min bound + in-graph coefficients) — records
wall-clock, parity against the single-device static driver, and the
plan's per-device flop estimate, and writes the machine-readable
``BENCH_grouped.json`` record (CPU rows prove layout/parity; a TPU run
of the same file regenerates honest wall-clock).

The sweep needs ``REPRO_BENCH_GROUPED_NDEV`` (default 8) devices, but
XLA's host-device count is fixed at jax import — so the ``run()`` suite
entry re-execs this module in a subprocess with XLA_FLAGS set, exactly
like the multi-device tests, and re-emits its rows.

  python -m benchmarks.grouped_scaling     (standalone: sets its own
                                            XLA_FLAGS before jax loads)
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

BENCH_JSON = os.environ.get("REPRO_BENCH_GROUPED_JSON", "BENCH_grouped.json")
NDEV = int(os.environ.get("REPRO_BENCH_GROUPED_NDEV", "8"))

if __name__ == "__main__":
    # must happen before any jax import in this process
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={NDEV}")
    os.environ.setdefault("JAX_ENABLE_X64", "1")


def _sweep():
    import jax
    import jax.numpy as jnp

    import repro.core as C
    import repro.solver as S
    from repro.dist import zolo_group_mesh
    from benchmarks.common import BENCH_N, emit, make_matrix, time_fn

    ndev = jax.device_count()
    n = min(BENCH_N, 256)
    m = 2 * n
    kappa = 1e4
    a = make_matrix(n, kappa, m=m, seed=17)

    # single-device reference at the r the auto path would use
    cfg = S.SvdConfig(kappa=kappa, l0_policy="estimate_at_plan")
    q_ref = None

    # static (plan-time schedule) and dynamic (runtime conditioning,
    # l0_policy="runtime") rows on every factorization: the dynamic
    # backend's price for serving any kappa from one executable is the
    # in-graph estimate + in-graph coefficients, visible as its
    # wall-clock delta at equal (r, sep)
    cfg_dyn = S.SvdConfig(l0_policy="runtime")

    records = []
    for r in [d for d in range(1, ndev + 1) if ndev % d == 0]:
        sep = ndev // r
        mesh = zolo_group_mesh(r)
        for label, c in (("static", cfg), ("dynamic", cfg_dyn)):
            p = S.plan(c, a.shape, a.dtype, mesh=mesh)
            assert p.mode == "grouped" and p.r == r and p.sep == sep
            if label == "dynamic":
                assert p.method == "zolo_grouped_dynamic", p.method
            q = p.polar(a, want_h=False)[0]
            if q_ref is None:
                ref = S.plan(S.SvdConfig(method="zolo_static", kappa=kappa,
                                         l0_policy="estimate_at_plan",
                                         r=r),
                             a.shape, a.dtype)
                q_ref = ref.polar(a, want_h=False)[0]
            t = time_fn(lambda x: p.polar(x, want_h=False)[0], a)
            orth = float(C.orthogonality(q))
            err = float(jnp.abs(q - q_ref).max())
            emit(f"grouped_scaling.{label}_r{r}_sep{sep}", t * 1e6,
                 f"method={p.method};flops_per_dev={p.flops_estimate:.3e};"
                 f"orth={orth:.2e};err_vs_ref={err:.2e}")
            records.append({
                "r": r, "sep": sep, "method": p.method,
                "schedule": label,
                "schedule_iters": (len(p.schedule)
                                   if p.schedule is not None else None),
                "us_per_call": t * 1e6,
                "flops_per_device": p.flops_estimate,
                "orth": orth, "max_err_vs_single_device": err,
            })

    record = {
        "suite": "grouped_scaling",
        "backend": jax.default_backend(),
        "ndev": ndev,
        "shape": [m, n],
        "dtype": str(jnp.dtype(a.dtype)),
        "kappa": kappa,
        "records": records,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2)
    emit("grouped_scaling.json_record", 0.0, BENCH_JSON)


def run():
    """Suite entry for ``benchmarks.run``: re-exec with NDEV virtual
    devices when this process has too few (the harness process imported
    jax long ago), re-emitting the subprocess rows."""
    import jax
    from benchmarks.common import emit

    if jax.device_count() >= NDEV:
        _sweep()
        return
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={NDEV}",
        JAX_ENABLE_X64="1")
    out = subprocess.run([sys.executable, "-m", "benchmarks.grouped_scaling"],
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(
            f"grouped_scaling subprocess failed:\n{out.stderr[-2000:]}")
    for line in out.stdout.splitlines():
        if not line.startswith("grouped_scaling."):
            continue
        # re-emit through the harness CSV: name,us,derived
        parts = line.split(",", 2)
        emit(parts[0], float(parts[1]), parts[2] if len(parts) > 2 else "")
    if not os.path.exists(BENCH_JSON):
        raise RuntimeError(f"{BENCH_JSON} was not written")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    _sweep()
