"""Pallas kernel parity (interpret mode vs jnp oracle, flop accounting).

Wall-clock in interpret mode is meaningless (Python-executed kernel body);
the reported numbers are oracle wall-clock + the VMEM working-set model of
the chosen BlockSpecs — the structural facts that transfer to TPU.

``run`` also drives the registered ``zolo_pallas`` backend end-to-end
against the XLA ``zolo_static`` path through ``repro.solver`` plans and
writes the comparison as a ``BENCH_kernels.json`` record (backend, tile
sizes, parity error, wall-clock): the machine-readable artifact a TPU
run regenerates with compiled kernels.
"""

from __future__ import annotations

import json
import os

import numpy as np

import jax
import jax.numpy as jnp
from repro.kernels import ops, ref
from benchmarks.common import BENCH_N, emit, time_fn

BENCH_JSON = os.environ.get("REPRO_BENCH_KERNELS_JSON", "BENCH_kernels.json")


def vmem_working_set(bn, bk, bm=None, dtype_bytes=4):
    """Bytes resident in VMEM for one grid step of the gram kernel."""
    a_tiles = 2 * bk * bn * dtype_bytes
    out_tile = bn * bn * 4
    return a_tiles + out_tile


def run():
    n = min(BENCH_N, 512)
    m = 2 * n
    rng = np.random.default_rng(0)
    a32 = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)

    t_ref = time_fn(jax.jit(lambda a: ref.gram_ref(a, 0.5)), a32)
    emit("kernels.gram.oracle", t_ref * 1e6,
         f"flops={2 * m * n * n:.2e}")
    g_k = ops.gram(a32, 0.5)
    g_r = ref.gram_ref(a32, 0.5)
    emit("kernels.gram.max_err_vs_oracle", 0.0,
         f"{float(jnp.abs(g_k - g_r).max()):.2e}")
    ws = vmem_working_set(256, 512)
    emit("kernels.gram.vmem_working_set", 0.0,
         f"{ws / 1e6:.2f}MB_of_128MB_vmem_v5e")

    r = 3
    t = jnp.asarray(rng.standard_normal((r, m, n)), jnp.float32)
    avec = jnp.asarray(rng.standard_normal(r), jnp.float32)
    o_k = ops.polar_update(a32, t, avec, 0.99)
    o_r = ref.polar_update_ref(a32, t, avec, 0.99)
    emit("kernels.polar_update.max_err", 0.0,
         f"{float(jnp.abs(o_k - o_r).max()):.2e}")
    # HBM traffic saving of the fusion: naive chaining reads/writes the
    # (m, n) array 2r+2 times; fused reads r+1, writes 1.
    naive = (2 * r + 2) * m * n * 4
    fused = (r + 2) * m * n * 4
    emit("kernels.polar_update.hbm_traffic_saving", 0.0,
         f"{naive / fused:.2f}x")
    flash_bench()
    end_to_end()


def _selected_tiles(m, n, dtype):
    """The tile sizes the wrappers will actually run for this problem:
    the backend-default requests shrunk by ``_pick_tile`` under the
    dtype's MXU lane alignment (bf16 packs 256 lanes per native tile
    where f32 packs 128 — the alignment bug this suite regression-guards
    by asserting every recorded row's tiles)."""
    from repro.kernels.ops import _pick_tile, _tile_align

    align = _tile_align(dtype)
    requested = {"bn": 256, "bk": 512, "bm": 256}
    selected = {"bn": _pick_tile(n, requested["bn"], align),
                "bk": _pick_tile(m, requested["bk"], align),
                "bm": _pick_tile(m, requested["bm"], align)}
    for k, t in selected.items():
        if t % align:
            raise RuntimeError(
                f"selected tile {k}={t} breaks the {align}-lane MXU "
                f"alignment for {jnp.dtype(dtype).name} — the dtype-"
                f"aware _pick_tile contract regressed")
    return requested, selected, align


def end_to_end():
    """zolo_pallas vs zolo_static through repro.solver plans, one row
    per compute precision (f32 and bf16): the full polar solve, kernel
    ops vs XLA ops, wall-clock + parity against the f64 oracle polar
    factor, written to BENCH_kernels.json.  Interpret-mode wall-clock
    only shows the Python-execution overhead on CPU — each row carries
    the ``interpret`` tag so CPU CI reads the rows as parity-only and a
    TPU run of the same file is the performance artifact (acceptance
    there: the bf16 row's solve >= 1.5x the f32 row's)."""
    from repro.core import orthogonality
    from benchmarks.common import kernel_vs_xla_polar

    n = min(BENCH_N, 256)
    m = 2 * n
    kappa = 1e3
    rng = np.random.default_rng(3)
    u, _ = np.linalg.qr(rng.standard_normal((m, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    s = np.geomspace(1.0, 1.0 / kappa, n)
    a64 = (u * s) @ v.T
    q64 = u @ v.T  # exact polar factor: the f64 parity oracle
    a = jnp.asarray(a64, jnp.float32)
    backend = jax.default_backend()
    interpret = backend != "tpu"

    rows = []
    for compute in ("float32", "bfloat16"):
        t_xla, t_ker, err_xla, p_ker = kernel_vs_xla_polar(
            a, l0=0.9 / kappa, r=2,
            compute_dtype=None if compute == "float32" else compute)
        q_ker = p_ker.polar(a, want_h=False)[0]
        # oracle parity on the host in f64 (device x64 may be disabled)
        err_f64 = float(np.abs(np.asarray(q_ker, np.float64) - q64).max())
        requested, selected, align = _selected_tiles(
            m, n, jnp.dtype(compute))
        emit(f"kernels.zolo_pallas.end_to_end_{compute}", t_ker * 1e6,
             f"xla={t_xla * 1e6:.1f}us;max_err_vs_f64={err_f64:.2e};"
             f"interpret={interpret}")
        rows.append({
            "compute_dtype": compute,
            "interpret": interpret,
            "iterations": len(p_ker.schedule),
            "lane_align": align,
            "tiles_requested": requested,
            "tiles_selected": selected,
            "zolo_static_us": t_xla * 1e6,
            "zolo_pallas_us": t_ker * 1e6,
            "max_err_vs_f64_oracle": err_f64,
            "max_err_vs_xla": err_xla,
            "orth_zolo_pallas": float(orthogonality(q_ker)),
        })

    f32_row, bf16_row = rows
    record = {
        "suite": "kernels_end_to_end",
        "backend": backend,
        "interpret": interpret,
        "shape": [m, n],
        "dtype": "float32",
        "kappa": kappa,
        "r": 2,
        # rows are per compute precision; on TPU the interesting derived
        # number is the bf16 row's speedup over f32 (CPU interpret rows
        # are parity-only — Python-executed kernel bodies time nothing)
        "rows": rows,
        "bf16_speedup_vs_f32": (f32_row["zolo_pallas_us"]
                                / bf16_row["zolo_pallas_us"]),
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2)
    emit("kernels.zolo_pallas.json_record", 0.0, BENCH_JSON)


def flash_bench():
    """Flash-attention kernel parity + VMEM model (appended to run())."""
    rng = np.random.default_rng(1)
    b, s, h, d = 2, 256, 4, 64
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    got = ops.flash_attention(q, k, v, bq=128, bk=128)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    emit("kernels.flash_attention.max_err", 0.0,
         f"{float(jnp.abs(got - jnp.asarray(want, jnp.float32)).max()):.2e}")
    # VMEM per grid step: q,k,v tiles + f32 state
    bq = bk = 128
    ws = (bq * d + 2 * bk * d) * 4 + (2 * bq + bq * d) * 4
    emit("kernels.flash_attention.vmem_working_set", 0.0,
         f"{ws / 1e6:.2f}MB_of_128MB_vmem_v5e")
