"""Paper Table 6: QDWH-PD vs Zolo-PD, plus the gram-sharing ablation.

On one CPU there is no subgroup parallelism, so the wall-clock comparison
shows the *serial* trade (Zolo spends more flops per iteration, saves
iterations); the flop model shows the per-group parallel cost the paper's
speedups come from (critical path / r).

Both solvers run through ``repro.solver`` plans, so the timed repeats
reuse one compiled executable per configuration.
"""

from __future__ import annotations

import repro.solver as S
from repro.dist.grouped import grouped_iteration_flops

from benchmarks.common import BENCH_N, emit, make_matrix, time_fn


def run():
    n = BENCH_N
    for name, kappa in (("fv1", 1.4e1), ("linverse", 9.06e3),
                        ("bcsstk18", 3.46e11)):
        a = make_matrix(n, kappa, m=n, seed=2)
        extra = (("alpha", 1.0), ("l", 0.9 / kappa))
        qdwh = S.plan(S.SvdConfig(method="qdwh", extra=extra),
                      a.shape, a.dtype)
        zolo = S.plan(S.SvdConfig(method="zolo", r=2, extra=extra),
                      a.shape, a.dtype)
        t_q = time_fn(lambda x: qdwh.polar(x, want_h=False)[0], a)
        t_z = time_fn(lambda x: zolo.polar(x, want_h=False)[0], a)
        emit(f"table6.{name}.qdwh_pd", t_q * 1e6, "")
        emit(f"table6.{name}.zolo_pd_r2", t_z * 1e6,
             f"serial_ratio={t_q / t_z:.2f}x")
        _, _, iq = qdwh.polar(a, want_h=False)
        _, _, iz = zolo.polar(a, want_h=False)
        emit(f"table6.{name}.iters", 0.0,
             f"qdwh={int(iq.iterations)};zolo_r2={int(iz.iterations)}")

    # kernel-backed driver vs the XLA path, end to end through plans
    # (small n: off-TPU the Pallas kernels run in interpret mode, so the
    # wall-clock here measures Python kernel-body execution — the parity
    # number is the transferable fact; TPU wall-clock comes from
    # BENCH_kernels.json regenerated on hardware).
    import jax.numpy as jnp

    from benchmarks.common import kernel_vs_xla_polar

    nk = min(n, 256)
    kappa = 9.06e3
    ak = jnp.asarray(make_matrix(nk, kappa, m=nk, seed=3), jnp.float32)
    t_xla, t_ker, err, _ = kernel_vs_xla_polar(ak, l0=0.9 / kappa, r=2)
    emit("table6.zolo_pallas_vs_xla", t_ker * 1e6,
         f"xla={t_xla * 1e6:.1f}us;max_err={err:.2e}")

    # parallel cost model (per-group critical path), paper's setting r=2:
    m = n
    iters_q, iters_z = 5, 4
    qdwh_flops = iters_q * (2 * m * n * n + n ** 3 / 3 + 2 * m * n * n)
    for r in (2, 4, 8):
        faithful = grouped_iteration_flops(m, n, r, iters_z, False)
        shared = grouped_iteration_flops(m, n, r, iters_z, True)
        # per-group (critical path) costs in the r-way parallel setting
        per_group_faithful = faithful / r
        emit(f"table6.model.r{r}.parallel_speedup_vs_qdwh", 0.0,
             f"{qdwh_flops / per_group_faithful:.2f}x")
        emit(f"table6.model.r{r}.gram_share_flop_saving", 0.0,
             f"{faithful / shared:.2f}x")
