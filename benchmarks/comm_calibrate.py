"""Calibrate ``comm_flops_per_word`` — the flop-equivalent cost the
grouped (Algorithm 3) cost model charges per psum word.

``repro.dist.grouped.grouped_iteration_flops`` prices the two
collectives of a sep>1 mesh (the n^2-word "sep" Gram reduction and the
(mn/sep)-word "zolo" combine) at a flat ``comm_flops_per_word`` — a
round-number prior of 32 until measured.  This suite measures it: the
device's matmul flop rate (how many flops fit in a second) and the
all-reduce wall-clock per word on the local mesh, whose ratio is the
flop-equivalents one psum word costs.  The committed ``BENCH_comm.json``
records the CPU calibration (layout-honest; a TPU run of the same file
regenerates honest interconnect numbers), and a calibrated value threads
into planning via ``SvdConfig.extra["comm_flops_per_word"]`` — scored by
every registered ``flops_fn``, never passed to the backend.

Like ``grouped_scaling``, the sweep needs ``REPRO_BENCH_GROUPED_NDEV``
(default 8) devices, so the ``run()`` suite entry re-execs this module
in a subprocess with XLA_FLAGS set and re-emits its rows.

  python -m benchmarks.comm_calibrate     (standalone: sets its own
                                           XLA_FLAGS before jax loads)
"""

from __future__ import annotations

import functools
import json
import os
import subprocess
import sys

BENCH_JSON = os.environ.get("REPRO_BENCH_COMM_JSON", "BENCH_comm.json")
NDEV = int(os.environ.get("REPRO_BENCH_GROUPED_NDEV", "8"))

if __name__ == "__main__":
    # must happen before any jax import in this process
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={NDEV}")
    os.environ.setdefault("JAX_ENABLE_X64", "1")


def _calibrate_dtype(dtype, mesh, ndev, n):
    """Measure one dtype's (matmul flop rate, psum cost records,
    suggested flops-per-word).  A psum word is one element of the
    reduced array — bf16 words are half the bytes of f32 words, so the
    flop-equivalent cost per word genuinely differs per dtype (that is
    what a bf16 compute plan's cost model should be fed)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from benchmarks.common import emit, time_fn

    name = jnp.dtype(dtype).name

    # --- compute rate: the flop side of the flop-equivalent ----------
    a = jnp.ones((n, n), dtype)
    t_mm = time_fn(jax.jit(lambda x: x @ x), a)
    flop_rate = 2.0 * n ** 3 / t_mm  # flops / s
    emit(f"comm_calibrate.matmul_rate_{name}", t_mm * 1e6,
         f"n={n};flops_per_s={flop_rate:.3e}")

    # --- collective rate: psum wall-clock per word on the local mesh --
    records = []
    for words in (64 * 64, 128 * 128, 256 * 256):
        side = int(words ** 0.5)
        x = jnp.ones((ndev * side, side), dtype)

        @jax.jit
        @functools.partial(shard_map, mesh=mesh,
                           in_specs=P("sep", None), out_specs=P("sep", None))
        def allreduce(blk):
            # each device contributes its (side, side) block; one psum
            # over "sep" — the per-grid DGSUM2D this model prices
            return jnp.broadcast_to(
                jax.lax.psum(blk[:side], "sep"), blk.shape)

        t_ps = time_fn(allreduce, x)
        per_word = t_ps / words
        flops_per_word = per_word * flop_rate
        emit(f"comm_calibrate.psum_{side}x{side}_{name}", t_ps * 1e6,
             f"words={words};flops_per_word={flops_per_word:.1f}")
        records.append({"words": words, "us_per_psum": t_ps * 1e6,
                        "flops_per_word": flops_per_word})

    # suggest the mid-size measurement (small psums are latency-bound,
    # large ones bandwidth-bound; the Gram reduction sits in between)
    suggested = sorted(r["flops_per_word"]
                       for r in records)[len(records) // 2]
    return flop_rate, records, suggested


def _calibrate():
    import jax
    import jax.numpy as jnp

    from repro.dist import zolo_group_mesh
    from benchmarks.common import BENCH_N, emit

    ndev = jax.device_count()
    n = min(BENCH_N, 256)

    # the "sep" axis spans every device (zolo_group_mesh(1)), matching
    # the Gram-reduction collective of a maximally-distributed group
    mesh = zolo_group_mesh(1)

    # per-dtype calibration: f64 (the committed reference), f32 and
    # bf16 (the compute_dtype production precisions — their psum words
    # are narrower, and on real interconnects the flop-equivalent cost
    # per word is not the f64 value scaled by itemsize)
    per_dtype = {}
    for dtype in (jnp.float64, jnp.float32, jnp.bfloat16):
        name = jnp.dtype(dtype).name
        flop_rate, records, suggested = _calibrate_dtype(dtype, mesh,
                                                         ndev, n)
        per_dtype[name] = {
            "word_bytes": jnp.dtype(dtype).itemsize,
            "matmul_flops_per_s": flop_rate,
            "records": records,
            "comm_flops_per_word": suggested,
        }

    ref = per_dtype["float64"]
    record = {
        "suite": "comm_calibrate",
        "backend": jax.default_backend(),
        "ndev": ndev,
        # top-level keys stay the f64 reference calibration (the shape
        # earlier consumers of BENCH_comm.json read); per-dtype rows
        # live under "dtypes"
        "dtype": "float64",
        "word_bytes": ref["word_bytes"],
        "matmul_flops_per_s": ref["matmul_flops_per_s"],
        "records": ref["records"],
        "comm_flops_per_word": ref["comm_flops_per_word"],
        "dtypes": per_dtype,
        "usage": "SvdConfig(extra=(('comm_flops_per_word', "
                 f"{ref['comm_flops_per_word']:.1f}),)) — or export "
                 "REPRO_COMM_FLOPS_PER_WORD=<value> to rebase the "
                 "DEFAULT_COMM_FLOPS_PER_WORD prior for every plan "
                 "in the process",
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2)
    emit("comm_calibrate.json_record", 0.0,
         f"{BENCH_JSON};comm_flops_per_word="
         f"{ref['comm_flops_per_word']:.1f}")


def run():
    """Suite entry for ``benchmarks.run``: re-exec with NDEV virtual
    devices when this process has too few, re-emitting the subprocess
    rows (same protocol as ``grouped_scaling``)."""
    import jax
    from benchmarks.common import emit

    if jax.device_count() >= NDEV:
        _calibrate()
        return
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={NDEV}",
        JAX_ENABLE_X64="1")
    out = subprocess.run([sys.executable, "-m", "benchmarks.comm_calibrate"],
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(
            f"comm_calibrate subprocess failed:\n{out.stderr[-2000:]}")
    for line in out.stdout.splitlines():
        if not line.startswith("comm_calibrate."):
            continue
        parts = line.split(",", 2)
        emit(parts[0], float(parts[1]), parts[2] if len(parts) > 2 else "")
    if not os.path.exists(BENCH_JSON):
        raise RuntimeError(f"{BENCH_JSON} was not written")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    _calibrate()
