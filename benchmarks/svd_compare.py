"""Paper Tables 4 & 9: Zolo-SVD / QDWH-SVD vs the direct SVD baseline.

``jnp.linalg.svd`` plays PDGESVD (the vendor-tuned bidiagonalization
baseline); the serial CPU ratio understates the paper's parallel speedups
(which come from subgroup scaling — see the dry-run collective analysis),
so iteration counts and flop shares are reported alongside.

Both iterative solvers run through ``repro.solver`` plans: the timed
repeats hit one compiled executable per (shape, dtype, config) — the
heavy-repeated-traffic path — instead of re-tracing per call.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

import repro.solver as S

from benchmarks.common import BENCH_N, emit, make_matrix, time_fn


def run():
    n = BENCH_N
    for name, kappa in (("nemeth03", 1.29), ("fv1", 1.4e1),
                        ("rand1", 3.97e7)):
        a = make_matrix(n, kappa, m=n, seed=4)
        baseline = jax.jit(
            lambda a_: jnp.linalg.svd(a_, full_matrices=False))
        extra = (("alpha", 1.0), ("l", 0.9 / kappa))
        zolo = S.plan(S.SvdConfig(method="zolo", r=2, extra=extra),
                      a.shape, a.dtype)
        qdwh = S.plan(S.SvdConfig(method="qdwh", extra=extra),
                      a.shape, a.dtype)
        t_b = time_fn(baseline, a)
        t_z = time_fn(zolo.svd, a)
        t_q = time_fn(qdwh.svd, a)
        emit(f"table4.{name}.pdgesvd_role", t_b * 1e6, "")
        emit(f"table4.{name}.zolo_svd", t_z * 1e6,
             f"serial_speedup={t_b / t_z:.2f}x")
        emit(f"table4.{name}.qdwh_svd", t_q * 1e6,
             f"serial_speedup={t_b / t_q:.2f}x")
        # accuracy parity with the baseline (paper: "as accurate as")
        u, s, vh = zolo.svd(a)
        s0 = np.linalg.svd(np.asarray(a), compute_uv=False)
        emit(f"table4.{name}.sv_abs_err", 0.0,
             f"{float(np.abs(np.asarray(s) - s0).max()):.2e}")
