"""§Roofline builder: dry-run JSONs -> per-cell roofline terms.

Hardware model (TPU v5e, per the brief):
    peak bf16 compute   197 TFLOP/s / chip
    HBM bandwidth       819 GB/s  / chip
    ICI link bandwidth  ~50 GB/s / link

Terms (per device = per chip; cost_analysis is per-partition):
    compute_s    = HLO_flops / 197e12
    memory_s     = HLO_bytes_accessed / 819e9
    collective_s = wire_bytes / 50e9     (ring-cost estimate per device)

MODEL_FLOPS is the analytic useful work: 6*N*D for dense training
(2*N*D serving), with N the matmul-visible active params (MoE experts
scaled by top_k/E) plus the attention O(S^2) term.  The ratio
MODEL_FLOPS / HLO_FLOPS exposes remat/dispatch/redundancy overheads.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Optional

from repro import configs as CFG
from repro.models.config import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_PER_CHIP = 16e9  # v5e


def matmul_params(cfg) -> float:
    """Active matmul-visible params (excl. embedding gather; incl. head)."""
    d = cfg.d_model
    per_layer = {}
    n_attn = 0.0
    if "attn" in cfg.block_pattern:
        n_attn = d * cfg.q_dim * 2 + d * cfg.kv_dim * 2
    n_mlp = 0.0
    if cfg.mlp_type == "swiglu":
        n_mlp = 3 * d * cfg.d_ff
    elif cfg.mlp_type == "gelu":
        n_mlp = 2 * d * cfg.d_ff
    if cfg.num_experts:
        n_mlp = n_mlp * cfg.moe_top_k  # active experts only
        n_mlp += d * cfg.num_experts  # router
    n_ssd = 0.0
    if "ssd" in cfg.block_pattern:
        di, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        n_ssd = d * (2 * di + 2 * ns + h) + di * d
    n_rglru = 0.0
    if "rglru" in cfg.block_pattern:
        dr = cfg.rnn_width
        n_rglru = 2 * d * dr + 2 * dr * dr + dr * d

    pat = cfg.block_pattern
    counts = {k: (list(pat).count(k) * cfg.num_stages
                  + list(cfg.remainder_blocks).count(k))
              for k in ("attn", "ssd", "rglru")}
    total = counts["attn"] * (n_attn + n_mlp) \
        + counts["ssd"] * n_ssd \
        + counts["rglru"] * (n_rglru + n_mlp)
    total += d * cfg.vocab_padded  # lm head (tied or not, the matmul runs)
    return float(total)


def attention_flops(cfg, shape) -> float:
    """O(S^2) attention matmul flops (fwd), full batch."""
    if "attn" not in cfg.block_pattern:
        return 0.0
    n_attn_layers = (list(cfg.block_pattern).count("attn") * cfg.num_stages
                     + list(cfg.remainder_blocks).count("attn"))
    s = shape.seq_len
    if shape.kind == "decode":
        ctx = min(s, cfg.window) if cfg.window else s
        per = 4.0 * ctx * cfg.q_dim  # qk + pv for one new token
        return n_attn_layers * shape.global_batch * per
    window = min(s, cfg.window) if cfg.window else s
    per_tok = 4.0 * (window / 2 if window == s else window) * cfg.q_dim
    return n_attn_layers * shape.global_batch * s * per_tok


def model_flops(arch: str, shape_name: str) -> float:
    cfg = CFG.get_config(arch)
    shape = SHAPES[shape_name]
    n = matmul_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens + 3.0 * attention_flops(cfg, shape)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens + attention_flops(cfg, shape)
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch + attention_flops(cfg, shape)


def load_cells(dryrun_dir: str):
    cells = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def analyze_cell(rec: dict) -> Optional[dict]:
    if rec.get("status") == "skip":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "skip": True}
    if rec.get("status") != "ok":
        return None
    chips = rec.get("devices", 256)
    # Two cost readings, each a *lower bound* with a different failure
    # mode: the raw full-lowering cost undercounts any loop XLA kept as a
    # while (e.g. the decode stage scan is costed once), while the 1/2-
    # stage extrapolation undercounts ceil-padded batched work (e.g. the
    # Muon stack sharded over 256 ways).  Take the max of the two.
    ce = rec.get("cost_extrapolated") or {}
    raw = rec.get("cost", {})
    flops = max(ce.get("flops") or 0.0, raw.get("flops") or 0.0)
    bytes_acc = max(ce.get("bytes") or 0.0,
                    raw.get("bytes accessed") or 0.0)
    coll_e = ce.get("collectives") or {}
    coll_r = rec.get("collectives") or {}
    wire = max(coll_e.get("total_wire_bytes") or 0.0,
               coll_r.get("total_wire_bytes")
               or coll_r.get("total_bytes") or 0.0)
    coll = coll_e if (coll_e.get("total_wire_bytes") or 0.0) >= \
        (coll_r.get("total_wire_bytes") or 0.0) else coll_r

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = wire / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    mf = model_flops(rec["arch"], rec["shape"])
    mf_per_chip = mf / chips
    useful_ratio = mf_per_chip / flops if flops else 0.0
    # roofline fraction: useful flops per chip / (peak * bound time)
    frac = mf_per_chip / (PEAK_FLOPS * bound_s) if bound_s else 0.0
    # fraction > 1 is impossible on real hardware: it means both cost
    # readings undercount (e.g. a retained scan); flag instead of report
    undercount = frac > 1.0 or useful_ratio > 10.0
    mem = rec.get("memory", {})
    hbm = (mem.get("temp_size_in_bytes", 0)
           + mem.get("argument_size_in_bytes", 0))
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops_total": mf, "hlo_flops_per_chip": flops,
        "useful_ratio": useful_ratio, "roofline_fraction": frac,
        "undercount_flag": undercount,
        "hbm_bytes": hbm, "fits_hbm": hbm <= HBM_PER_CHIP,
        "collectives_by_kind": {
            k: v for k, v in coll.items()
            if isinstance(v, dict) and v.get("wire_bytes", v.get("bytes", 0))
        },
        "skip": False,
    }


def build_table(dryrun_dir: str = "experiments/dryrun",
                mesh: str = "16x16"):
    rows = []
    for rec in load_cells(dryrun_dir):
        if rec.get("mesh") != mesh:
            continue
        a = analyze_cell(rec)
        if a:
            rows.append(a)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | useful (6ND/HLO) | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    body = []
    for r in rows:
        if r.get("skip"):
            body.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"SKIP(full-attn) | — | — |")
            continue
        frac = (f"{r['roofline_fraction']:.3f}"
                if not r.get("undercount_flag")
                else "n/a (HLO undercount)")
        body.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | {frac} |")
    return hdr + "\n".join(body) + "\n"


def run():
    from benchmarks.common import emit
    rows = build_table()
    ok = [r for r in rows if not r.get("skip")
          and not r.get("undercount_flag")]
    if not ok:
        emit("roofline.cells", 0.0, "no dry-run data yet")
        return
    emit("roofline.cells_analyzed", 0.0, str(len(ok)))
    worst = min(ok, key=lambda r: r["roofline_fraction"])
    best = max(ok, key=lambda r: r["roofline_fraction"])
    emit("roofline.worst_cell", 0.0,
         f"{worst['arch']}/{worst['shape']}={worst['roofline_fraction']:.3f}")
    emit("roofline.best_cell", 0.0,
         f"{best['arch']}/{best['shape']}={best['roofline_fraction']:.3f}")
    by_dom = {}
    for r in ok:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    emit("roofline.dominant_histogram", 0.0,
         ";".join(f"{k}={v}" for k, v in sorted(by_dom.items())))


if __name__ == "__main__":
    import sys
    rows = build_table(sys.argv[1] if len(sys.argv) > 1
                       else "experiments/dryrun")
    print(markdown_table(rows))
