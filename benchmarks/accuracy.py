"""Paper Figure 2: backward residual + singular-vector orthogonality for
the nine test matrices (synthetic stand-ins with matched n-ratio, kappa)."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp
import repro.core as C
from repro.configs.svd_paper import MATRICES, synthesize

from benchmarks.common import BENCH_N, emit


def run():
    for i, (name, cfg) in enumerate(sorted(MATRICES.items()), 1):
        a = jnp.asarray(synthesize(name, cpu_size=True))
        kappa = cfg.cond
        for method in ("zolo", "qdwh"):
            kw = dict(alpha=1.0, l=0.9 / kappa)
            if method == "zolo":
                kw["r"] = cfg.r_paper if cfg.r_paper <= 4 else 2
            u, s, vh = C.polar_svd(a, method=method, **kw)
            res = float(C.svd_residual(a, u, s, vh))
            orth_l = float(C.orthogonality(u))
            orth_r = float(C.orthogonality(vh.T))
            emit(f"fig2.{name}.{method}.residual", 0.0, f"{res:.2e}")
            emit(f"fig2.{name}.{method}.orth", 0.0,
                 f"L={orth_l:.2e};R={orth_r:.2e}")
        # baseline parity
        u0, s0, vh0 = jnp.linalg.svd(a, full_matrices=False)
        emit(f"fig2.{name}.baseline.residual", 0.0,
             f"{float(C.svd_residual(a, u0, s0, vh0)):.2e}")
