"""RG-LRU recurrent block (Griffin / RecurrentGemma).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * r_t),  r_t, i_t input-dependent gates.

Prefill/train uses ``lax.associative_scan`` (log-depth on TPU); decode is
a single fused step on the (b, d_rnn) state — O(1) per token, which is why
recurrentgemma runs the ``long_500k`` shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

_C = 8.0


def rglru_init(key, cfg, dtype):
    d = cfg.d_model
    dr = cfg.rnn_width
    ks = jax.random.split(key, 6)
    return {
        "in_x": L.truncated_normal_init(ks[0], (d, dr), 1.0, dtype),
        "in_gate": L.truncated_normal_init(ks[1], (d, dr), 1.0, dtype),
        "conv_w": (0.1 * jax.random.normal(
            ks[2], (cfg.conv_width, dr), jnp.float32)).astype(dtype),
        "w_a": L.truncated_normal_init(ks[3], (dr, dr), 1.0, dtype),
        "w_i": L.truncated_normal_init(ks[4], (dr, dr), 1.0, dtype),
        "lam": jnp.log(jnp.expm1(  # softplus^-1 of rates in (0.9, 0.999)
            -jnp.log(jnp.linspace(0.9, 0.999, dr)) / _C)).astype(jnp.float32),
        "out_proj": L.truncated_normal_init(ks[5], (dr, d), 1.0, dtype),
    }


def rglru_axes(cfg, stacked: bool):
    lead = ("layers",) if stacked else ()
    return {
        "in_x": lead + ("embed", "state"),
        "in_gate": lead + ("embed", "state"),
        "conv_w": lead + (None, "state"),
        "w_a": lead + ("state", None),
        "w_i": lead + ("state", None),
        "lam": lead + (None,),
        "out_proj": lead + ("state", "embed"),
    }


def _gates(params, xr):
    """a_t (log-space) and gated input.  xr: (b, s, dr) f32."""
    r = jax.nn.sigmoid(jnp.einsum("bsr,rk->bsk", xr, params["w_a"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rk->bsk", xr, params["w_i"])
                       .astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"])[None, None] * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) with a = exp(log_a): use expm1 for stability
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    gated = mult * i * xr.astype(jnp.float32)
    return a, gated


def _causal_conv(x, w, cache=None):
    width = w.shape[0]
    pad = (jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
           if cache is None else cache)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(width))
    return out, xp[:, -(width - 1):]


def rglru_forward(params, x, cfg, *, init_state=None, conv_cache=None):
    """x: (b, s, d) -> (b, s, d); returns (out, (state, conv_tail))."""
    b, s, d = x.shape
    xb = jnp.einsum("bsd,dr->bsr", x, params["in_x"])
    gate = jnp.einsum("bsd,dr->bsr", x, params["in_gate"])
    xb, conv_tail = _causal_conv(xb, params["conv_w"], conv_cache)
    a, u = _gates(params, xb)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    av, bv = jax.lax.associative_scan(combine, (a, u), axis=1)
    h = bv
    if init_state is not None:
        h = h + av * init_state.astype(jnp.float32)[:, None, :]
    state = h[:, -1]
    out = h.astype(x.dtype) * jax.nn.gelu(
        gate.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsr,rd->bsd", out, params["out_proj"]), \
        (state, conv_tail)


def rglru_decode(params, x, cache, cfg):
    """One-token decode.  x: (b, 1, d); cache = (state (b, dr), conv_tail)."""
    state, conv_tail = cache
    xb = jnp.einsum("bsd,dr->bsr", x, params["in_x"])
    gate = jnp.einsum("bsd,dr->bsr", x, params["in_gate"])
    xb, conv_tail = _causal_conv(xb, params["conv_w"], conv_tail)
    a, u = _gates(params, xb)
    h = a[:, 0] * state.astype(jnp.float32) + u[:, 0]
    out = h[:, None].astype(x.dtype) * jax.nn.gelu(
        gate.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsr,rd->bsd", out, params["out_proj"]), \
        (h, conv_tail)


def init_rglru_cache(cfg, batch: int, dtype):
    return (jnp.zeros((batch, cfg.rnn_width), jnp.float32),
            jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width), dtype))
