"""Mixture-of-Experts FFN with capacity-bounded scatter dispatch.

Dispatch is gather/scatter-based (memory-bound), NOT the GShard one-hot
einsum — the einsum dispatch costs O(S^2 * topk * d) flops at these shapes
and would dominate the roofline with fake compute.  Expert matmuls are a
single batched einsum over (E, C, d) buffers, so HLO flops are the honest
``tokens * topk * cf`` expert cost.

Tokens over capacity are dropped (standard capacity-factor semantics);
the router uses softmax-then-top-k with renormalized weights.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist.sharding import hint
from repro.models import layers as L


def moe_init(key, cfg, dtype):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": L.truncated_normal_init(ks[0], (d, e), 1.0, jnp.float32),
        "wi_gate": L.truncated_normal_init(ks[1], (e, d, ff), 1.0, dtype),
        "wi_up": L.truncated_normal_init(ks[2], (e, d, ff), 1.0, dtype),
        "wo": L.truncated_normal_init(ks[3], (e, ff, d), 1.0, dtype),
    }


def moe_axes(cfg, stacked: bool):
    lead = ("layers",) if stacked else ()
    return {
        "router": lead + ("embed", None),
        "wi_gate": lead + ("experts", "embed", "expert_mlp"),
        "wi_up": lead + ("experts", "embed", "expert_mlp"),
        "wo": lead + ("experts", "expert_mlp", "embed"),
    }


def moe_capacity(tokens: int, cfg) -> int:
    c = math.ceil(tokens * cfg.moe_top_k * cfg.capacity_factor
                  / cfg.num_experts)
    return max(8, c + (-c) % 8)


def moe_apply(params, x, cfg):
    """x: (b, s, d) -> (b, s, d)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    t = b * s
    cap = moe_capacity(t, cfg)
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)  # (t, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_i.reshape(t * k)
    # position of each (token, k) slot within its expert's buffer
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (t*k, e)
    pos = (jnp.cumsum(onehot, axis=0) - 1)  # running count per expert
    pos = jnp.sum(pos * onehot, axis=1)  # (t*k,)
    keep = pos < cap
    # scatter into (e, cap+1, d) — expert-major so the expert axis can
    # shard (EP); dropped slots land on each expert's trash row
    pos_c = jnp.where(keep, pos, cap)
    x_rep = jnp.repeat(xf, k, axis=0)  # (t*k, d)
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, pos_c].add(x_rep, mode="drop")
    buf = hint(buf, "experts", None, None)
    eb = buf[:, :cap]

    g = jnp.einsum("ecd,edf->ecf", eb, params["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", eb, params["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = hint(h, "experts", None, "expert_mlp")
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"])
    y = hint(y, "experts", None, None)

    yf = jnp.pad(y, ((0, 0), (0, 1), (0, 0)))  # restore the trash row
    out_slots = yf[flat_e, pos_c]  # (t*k, d); trash row -> zeros
    out_slots = out_slots * (keep[:, None] & True)
    w = (top_w.reshape(t * k).astype(jnp.float32)
         * keep.astype(jnp.float32))[:, None]
    out = (out_slots.astype(jnp.float32) * w).reshape(t, k, d).sum(axis=1)
    return out.reshape(b, s, d).astype(x.dtype), _aux_loss(probs, top_i, e)


def _aux_loss(probs, top_i, e):
    """Switch-style load-balancing auxiliary loss."""
    me = probs.mean(axis=0)  # (e,)
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    return e * jnp.sum(me * ce)
