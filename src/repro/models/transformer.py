"""Layer/stage assembly: pattern-scheduled blocks, scan-over-stages, remat.

A *layer* = temporal mixer (attn | rglru | ssd) + optional MLP (dense or
MoE).  A *stage* = one repetition of ``cfg.block_pattern``; the model scans
over ``num_stages`` stacked stages (+ an unstacked remainder, e.g.
recurrentgemma's 26 = 8 x (R,R,A) + (R,R)).  Scanning keeps the HLO small
enough that 512-way SPMD partitioning of a 60-layer model compiles fast.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as ATT
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RGL
from repro.models import ssm as SSD


# --- single layer -----------------------------------------------------------


def layer_init(key, kind: str, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": L.norm_param(cfg.d_model, cfg.norm_type)}
    if kind == "attn":
        p["mixer"] = ATT.attn_init(k1, cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = RGL.rglru_init(k1, cfg, dtype)
    elif kind == "ssd":
        p["mixer"] = SSD.ssd_init(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.mlp_type != "none":
        p["norm2"] = L.norm_param(cfg.d_model, cfg.norm_type)
        if cfg.num_experts:
            p["mlp"] = MOE.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type,
                                  dtype)
    return p


def layer_axes(kind: str, cfg, stacked: bool):
    lead = ("layers",) if stacked else ()
    ax: Dict[str, Any] = {"norm1": None if cfg.norm_type == "nonparam_ln"
                          else lead + (None,)}
    if kind == "attn":
        ax["mixer"] = ATT.attn_axes(cfg, stacked)
    elif kind == "rglru":
        ax["mixer"] = RGL.rglru_axes(cfg, stacked)
    elif kind == "ssd":
        ax["mixer"] = SSD.ssd_axes(cfg, stacked)
    if cfg.mlp_type != "none":
        ax["norm2"] = None if cfg.norm_type == "nonparam_ln" \
            else lead + (None,)
        if cfg.num_experts:
            ax["mlp"] = MOE.moe_axes(cfg, stacked)
        else:
            ax["mlp"] = L.mlp_axes(cfg.mlp_type, stacked)
    return ax


def layer_forward(params, kind: str, x, positions, cfg):
    """Full-sequence layer (train / prefill).  Returns (x, mixer_cache, aux)."""
    h = L.norm(x, params["norm1"], cfg.norm_type)
    aux = jnp.asarray(0.0, jnp.float32)
    if kind == "attn":
        mix, (k, v) = ATT.attn_forward(params["mixer"], h, positions, cfg)
        cache_out = (k, v)
    elif kind == "rglru":
        mix, (state, tail) = RGL.rglru_forward(params["mixer"], h, cfg)
        cache_out = (state, tail)
    else:  # ssd
        mix, (state, tail) = SSD.ssd_forward(params["mixer"], h, cfg)
        cache_out = (state, tail)
    x = x + mix
    if cfg.mlp_type != "none":
        h2 = L.norm(x, params["norm2"], cfg.norm_type)
        if cfg.num_experts:
            mlp_out, aux = MOE.moe_apply(params["mlp"], h2, cfg)
        else:
            mlp_out = L.mlp_apply(params["mlp"], h2, cfg.mlp_type)
        x = x + mlp_out
    return x, cache_out, aux


def layer_decode(params, kind: str, x, pos, cache, cfg):
    """One-token layer step.  Returns (x, new_cache, aux)."""
    h = L.norm(x, params["norm1"], cfg.norm_type)
    if kind == "attn":
        mix, cache = ATT.attn_decode(params["mixer"], h, pos, cache, cfg)
    elif kind == "rglru":
        mix, cache = RGL.rglru_decode(params["mixer"], h, cache, cfg)
    else:
        mix, cache = SSD.ssd_decode(params["mixer"], h, cache, cfg)
    x = x + mix
    if cfg.mlp_type != "none":
        h2 = L.norm(x, params["norm2"], cfg.norm_type)
        if cfg.num_experts:
            mlp_out, _ = MOE.moe_apply(params["mlp"], h2, cfg)
        else:
            mlp_out = L.mlp_apply(params["mlp"], h2, cfg.mlp_type)
        x = x + mlp_out
    return x, cache


def init_layer_cache(kind: str, cfg, batch: int, max_len: int, dtype):
    if kind == "attn":
        return ATT.init_attn_cache(cfg, batch, max_len, dtype)
    if kind == "rglru":
        return RGL.init_rglru_cache(cfg, batch, dtype)
    return SSD.init_ssd_cache(cfg, batch, dtype)


def prefill_layer_cache(kind: str, cfg, cache_shape_batch, max_len,
                        mixer_cache, dtype):
    """Convert a layer_forward mixer cache into the decode cache format."""
    if kind == "attn":
        k, v = mixer_cache
        empty = ATT.init_attn_cache(cfg, k.shape[0], max_len, dtype)
        return ATT.attn_fill_cache(empty, k, v, 0)
    return mixer_cache  # (state, conv_tail) already decode-shaped


# --- stages -----------------------------------------------------------------


def stage_init(key, cfg, dtype):
    keys = jax.random.split(key, len(cfg.block_pattern))
    return tuple(layer_init(k, kind, cfg, dtype)
                 for k, kind in zip(keys, cfg.block_pattern))


def stage_axes(cfg, stacked: bool):
    return tuple(layer_axes(kind, cfg, stacked)
                 for kind in cfg.block_pattern)


def stage_forward(params, x, positions, cfg):
    caches, aux = [], jnp.asarray(0.0, jnp.float32)
    for lp, kind in zip(params, cfg.block_pattern):
        x, cache, a = layer_forward(lp, kind, x, positions, cfg)
        caches.append(cache)
        aux = aux + a
    return x, tuple(caches), aux


def stage_decode(params, x, pos, caches, cfg):
    new = []
    for lp, kind, cache in zip(params, cfg.block_pattern, caches):
        x, c = layer_decode(lp, kind, x, pos, cache, cfg)
        new.append(c)
    return x, tuple(new)
