"""Shared layers: norms, rotary embeddings, MLPs, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale, dtype=jnp.float32):
    stddev = scale / max(1.0, (shape[-2] if len(shape) > 1 else shape[-1])) ** 0.5
    return (stddev * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def rms_norm(x, scale=None, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dt)


def nonparam_ln(x, eps: float = 1e-5):
    """OLMo's non-parametric LayerNorm (no scale, no bias)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def norm(x, params, norm_type: str):
    if norm_type == "nonparam_ln":
        return nonparam_ln(x)
    return rms_norm(x, params)


def norm_param(d: int, norm_type: str):
    return None if norm_type == "nonparam_ln" else jnp.zeros((d,), jnp.float32)


# --- rotary position embeddings -------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    ang = ang[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- MLPs ------------------------------------------------------------------


def mlp_init(key, d: int, ff: int, mlp_type: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "wi_gate": truncated_normal_init(k1, (d, ff), 1.0, dtype),
            "wi_up": truncated_normal_init(k2, (d, ff), 1.0, dtype),
            "wo": truncated_normal_init(k3, (ff, d), 1.0, dtype),
        }
    return {
        "wi": truncated_normal_init(k1, (d, ff), 1.0, dtype),
        "wo": truncated_normal_init(k2, (ff, d), 1.0, dtype),
    }


def mlp_axes(mlp_type: str, stacked: bool):
    lead = ("layers",) if stacked else ()
    if mlp_type == "swiglu":
        return {
            "wi_gate": lead + ("embed", "mlp"),
            "wi_up": lead + ("embed", "mlp"),
            "wo": lead + ("mlp", "embed"),
        }
    return {"wi": lead + ("embed", "mlp"), "wo": lead + ("mlp", "embed")}


def mlp_apply(params, x, mlp_type: str):
    if mlp_type == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, params["wi_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        return jnp.einsum("...f,fd->...d", h, params["wo"])
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["wo"])
