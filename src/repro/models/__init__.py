"""LM model stack covering the assigned architecture families."""

from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.model import (
    decode_step,
    forward,
    hidden_states,
    init_caches,
    init_params,
    param_count,
    params_axes,
    prefill,
)
