"""Public model API: init / forward / prefill / decode over the full stack.

Params pytree layout:

    {"embed":     (vocab_padded, d),
     "stages":    stage pytree stacked over num_stages (leading axis),
     "rem":       tuple of unstacked remainder layers (may be empty),
     "final_norm": scale or None,
     "lm_head":   (d, vocab_padded)}         (absent if tie_embeddings)

Stages are scanned (optionally rematerialized); the remainder runs inline.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import transformer as T


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_stages, k_rem, k_head = jax.random.split(key, 4)
    d = cfg.d_model
    params = {
        "embed": L.truncated_normal_init(
            k_embed, (cfg.vocab_padded, d), 1.0, dtype),
        "final_norm": L.norm_param(d, cfg.norm_type),
    }
    stage_keys = jax.random.split(k_stages, cfg.num_stages)
    stages = [T.stage_init(k, cfg, dtype) for k in stage_keys]
    params["stages"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stages)
    rem_keys = jax.random.split(k_rem, max(1, len(cfg.remainder_blocks)))
    params["rem"] = tuple(
        T.layer_init(k, kind, cfg, dtype)
        for k, kind in zip(rem_keys, cfg.remainder_blocks))
    if not cfg.tie_embeddings:
        params["lm_head"] = L.truncated_normal_init(
            k_head, (d, cfg.vocab_padded), 1.0, dtype)
    return params


def params_axes(cfg):
    ax = {
        "embed": ("vocab", "embed"),
        "final_norm": None if cfg.norm_type == "nonparam_ln" else (None,),
        "stages": T.stage_axes(cfg, stacked=True),
        "rem": tuple(T.layer_axes(kind, cfg, stacked=False)
                     for kind in cfg.remainder_blocks),
    }
    if not cfg.tie_embeddings:
        ax["lm_head"] = ("embed", "vocab")
    return ax


def _embed_inputs(params, batch, cfg):
    """tokens (b, s_tok) [+ prefix embeds (b, n_prefix, d)] -> (b, s, d)."""
    x = params["embed"][batch["tokens"]]
    if cfg.num_prefix_embeds:
        prefix = batch["embeds"].astype(x.dtype)
        x = jnp.concatenate([prefix, x], axis=1)
    return x


def backbone(params, x, positions, cfg):
    """Run stages (+ remainder) over a full sequence.

    Returns (hidden (b, s, d), per-stage mixer caches, moe aux loss)."""

    def stage_fn(carry, stage_params):
        x, aux = carry
        x, caches, a = T.stage_forward(stage_params, x, positions, cfg)
        return (x, aux + a), caches

    fn = stage_fn
    if cfg.remat:
        fn = jax.checkpoint(
            stage_fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_stages:
        (x, aux), caches = jax.lax.scan(fn, (x, jnp.asarray(0.0, jnp.float32)),
                                        params["stages"])
    else:
        caches_list = []
        carry = (x, jnp.asarray(0.0, jnp.float32))
        ns = cfg.num_stages
        for i in range(ns):
            sp = jax.tree.map(lambda p: p[i], params["stages"])
            carry, c = fn(carry, sp)
            caches_list.append(c)
        (x, aux) = carry
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches_list) \
            if caches_list else None

    rem_caches = []
    for lp, kind in zip(params["rem"], cfg.remainder_blocks):
        x, cache, a = T.layer_forward(lp, kind, x, positions, cfg)
        rem_caches.append(cache)
        aux = aux + a
    x = L.norm(x, params["final_norm"], cfg.norm_type)
    return x, (caches, tuple(rem_caches)), aux


def lm_head(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.logits_softcap:
        cap = cfg.logits_softcap
        logits = cap * jnp.tanh(logits.astype(jnp.float32) / cap)
    return logits


def forward(params, batch, cfg):
    """Training forward.  Returns (logits (b, s, vocab_padded), aux)."""
    x = _embed_inputs(params, batch, cfg)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    x, _, aux = backbone(params, x, positions, cfg)
    return lm_head(params, x, cfg), aux


def hidden_states(params, batch, cfg):
    """Training forward up to the final hidden states (loss computed
    chunked in train/step.py to avoid materializing full logits)."""
    x = _embed_inputs(params, batch, cfg)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, _, aux = backbone(params, x, positions, cfg)
    return x, aux


# --- serving ---------------------------------------------------------------


def init_caches(cfg, batch: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)

    def one_stage():
        return tuple(T.init_layer_cache(kind, cfg, batch, max_len, dtype)
                     for kind in cfg.block_pattern)

    stages = [one_stage() for _ in range(cfg.num_stages)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *stages)
    rem = tuple(T.init_layer_cache(kind, cfg, batch, max_len, dtype)
                for kind in cfg.remainder_blocks)
    return {"stages": stacked, "rem": rem, "pos": jnp.int32(0)}


def prefill(params, batch, cfg, max_len: int):
    """Run the prompt through the backbone and build decode caches.

    Returns (last_token_logits (b, vocab_padded), caches)."""
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x, (stage_mixer_caches, rem_mixer), _ = backbone(params, x, positions, cfg)

    def convert_stage(stage_caches):
        return tuple(
            T.prefill_layer_cache(kind, cfg, b, max_len, mc, dtype)
            for kind, mc in zip(cfg.block_pattern, stage_caches))

    # stage caches are stacked (num_stages, ...); convert leafwise
    converted = jax.vmap(convert_stage)(stage_mixer_caches)
    rem = tuple(
        T.prefill_layer_cache(kind, cfg, b, max_len, mc, dtype)
        for kind, mc in zip(cfg.remainder_blocks, rem_mixer))
    caches = {"stages": converted, "rem": rem, "pos": jnp.int32(s)}
    return lm_head(params, x[:, -1:], cfg)[:, 0], caches


def decode_step(params, tokens, caches, cfg):
    """One decode step.  tokens: (b, 1) int32.  Returns (logits, caches)."""
    pos = caches["pos"]
    x = params["embed"][tokens]

    def stage_fn(x, inp):
        stage_params, stage_cache = inp
        x, new_cache = T.stage_decode(stage_params, x, pos, stage_cache, cfg)
        return x, new_cache

    x, new_stage_caches = jax.lax.scan(
        stage_fn, x, (params["stages"], caches["stages"]))
    new_rem = []
    for lp, kind, cache in zip(params["rem"], cfg.remainder_blocks,
                               caches["rem"]):
        x, c = T.layer_decode(lp, kind, x, pos, cache, cfg)
        new_rem.append(c)
    x = L.norm(x, params["final_norm"], cfg.norm_type)
    logits = lm_head(params, x, cfg)[:, 0]
    return logits, {"stages": new_stage_caches, "rem": tuple(new_rem),
                    "pos": pos + 1}


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def caches_axes(cfg):
    """Logical axes for init_caches output (decode-shape dry-runs)."""

    def layer_axes(kind, stacked: bool):
        lead = ("layers",) if stacked else ()
        if kind == "attn":
            return {"k": lead + ("cache_batch", None, "cache_heads", None),
                    "v": lead + ("cache_batch", None, "cache_heads", None)}
        if kind == "rglru":
            return (lead + ("cache_batch", "state"),
                    lead + ("cache_batch", None, "state"))
        return (lead + ("cache_batch", "cache_heads", None, None),
                lead + ("cache_batch", None, "state"))

    return {
        "stages": tuple(layer_axes(kind, True)
                        for kind in cfg.block_pattern),
        "rem": tuple(layer_axes(kind, False)
                     for kind in cfg.remainder_blocks),
        "pos": "REPLICATED",
    }
