"""GQA attention: chunked-flash train/prefill, ring-buffer KV decode.

Pure-JAX blocked attention with online softmax (the TPU-friendly flash
formulation): the outer loop over query chunks is unrolled in Python
(static bounds -> causal and sliding-window chunks never touch keys they
cannot see), the inner loop is a ``lax.scan`` over key chunks carrying the
running (max, sum, acc).  Sliding windows slice a static [window + qc]
key range per query chunk, so SWA costs O(S * W), not O(S^2).

Decode uses a ring-buffer cache of capacity min(context, window): slot
``s`` at step ``pos`` holds absolute position ``pos - ((pos - s) % W)``.
RoPE is applied to keys at write time (absolute positions), so the ring
rotation needs no re-rotation.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


def attn_init(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "wq": L.truncated_normal_init(ks[0], (d, cfg.q_dim), 1.0, dtype),
        "wk": L.truncated_normal_init(ks[1], (d, cfg.kv_dim), 1.0, dtype),
        "wv": L.truncated_normal_init(ks[2], (d, cfg.kv_dim), 1.0, dtype),
        "wo": L.truncated_normal_init(ks[3], (cfg.q_dim, d), 1.0, dtype),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((cfg.head_dim,), jnp.float32)
        p["k_scale"] = jnp.zeros((cfg.head_dim,), jnp.float32)
    return p


def attn_axes(cfg, stacked: bool):
    lead = ("layers",) if stacked else ()
    ax = {
        "wq": lead + ("embed", "qkv"),
        "wk": lead + ("embed", "qkv"),
        "wv": lead + ("embed", "qkv"),
        "wo": lead + ("qkv", "embed"),
    }
    if cfg.qk_norm:
        ax["q_scale"] = lead + (None,)
        ax["k_scale"] = lead + (None,)
    return ax


def _project_qkv(params, x, positions, cfg):
    b, s, _ = x.shape
    kv, g, hd = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dq->bsq", x, params["wq"]).reshape(b, s, kv, g, hd)
    k = jnp.einsum("bsd,dq->bsq", x, params["wk"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,dq->bsq", x, params["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, params["q_scale"])
        k = L.rms_norm(k, params["k_scale"])
    q = L.apply_rope(q.reshape(b, s, kv * g, hd), positions,
                     cfg.rope_theta).reshape(b, s, kv, g, hd)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _flash_chunk(q, k, v, qpos, kpos, scale, kv_chunk,
                 window: Optional[int] = None):
    """Online-softmax attention of one query chunk against [k, v].

    q: (b, qc, kv, g, d); k/v: (b, sk, kv, d); qpos (qc,), kpos (sk,).
    """
    b, qc, kv, g, hd = q.shape
    sk = k.shape[1]
    nk = max(1, math.ceil(sk / kv_chunk))
    pad = nk * kv_chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kc = kv_chunk
    kpos = kpos.reshape(nk, kc)

    neg = jnp.asarray(-1e30, jnp.float32)
    m = jnp.full((b, kv, g, qc), neg, jnp.float32)
    l = jnp.zeros((b, kv, g, qc), jnp.float32)
    acc = jnp.zeros((b, kv, g, qc, hd), jnp.float32)

    # python-unrolled kv loop (counts are small and static): keeps XLA's
    # cost analysis honest (lax.scan bodies are costed once, not x trips)
    # and removes loop boundaries that block fusion.
    for i in range(nk):
        kb = jax.lax.dynamic_slice_in_dim(k, i * kc, kc, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * kc, kc, axis=1)
        kp = kpos[i]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = kp[None, None, None, None, :] <= qpos[None, None, None, :, None]
        if window is not None:
            mask = mask & (kp[None, None, None, None, :]
                           > qpos[None, None, None, :, None] - window)
        s = jnp.where(mask, s, neg)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb, preferred_element_type=jnp.float32)
        m = m_new
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4)  # (b, qc, kv, g, hd)


def flash_attention(q, k, v, q_positions, k_positions, *,
                    window: Optional[int] = None, q_chunk: int = 1024,
                    kv_chunk: int = 1024, scale: Optional[float] = None):
    """Causal (optionally sliding-window) attention.

    q: (b, sq, kv, g, hd); k/v: (b, sk, kv, hd).  Positions are absolute.
    Query chunks are unrolled (static causal/window bounds per chunk).
    """
    b, sq, kv, g, hd = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qc = min(q_chunk, sq)
    nq = math.ceil(sq / qc)
    outs = []
    for i in range(nq):
        lo = i * qc
        hi = min(sq, lo + qc)
        qi = q[:, lo:hi]
        qp = q_positions[lo:hi]
        # static key range this chunk can see (assumes q/k positions are
        # aligned suffixes: q_positions = k_positions[-sq:])
        k_hi = min(sk, hi + (sk - sq))
        k_lo = 0
        if window is not None:
            k_lo = max(0, lo + (sk - sq) - window + 1)
        ki = k[:, k_lo:k_hi]
        vi = v[:, k_lo:k_hi]
        kp = k_positions[k_lo:k_hi]
        outs.append(_flash_chunk(qi, ki, vi, qp, kp, scale, kv_chunk,
                                 window=window))
    return jnp.concatenate(outs, axis=1)


def attn_forward(params, x, positions, cfg, *, q_chunk=1024, kv_chunk=1024):
    """Training/prefill attention over a full sequence (causal)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(params, x, positions, cfg)
    out = flash_attention(q, k, v, positions, positions,
                          window=cfg.window, q_chunk=q_chunk,
                          kv_chunk=kv_chunk)
    out = out.reshape(b, s, cfg.q_dim).astype(x.dtype)
    return jnp.einsum("bsq,qd->bsd", out, params["wo"]), (k, v)


def cache_capacity(cfg, max_len: int) -> int:
    return min(max_len, cfg.window) if cfg.window else max_len


def init_attn_cache(cfg, batch: int, max_len: int, dtype):
    w = cache_capacity(cfg, max_len)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, w, kv, hd), dtype),
        "v": jnp.zeros((batch, w, kv, hd), dtype),
    }


def cache_positions(pos, w: int):
    """Absolute position stored in each ring slot at step ``pos``."""
    slots = jnp.arange(w)
    return pos - ((pos - slots) % w)


def attn_fill_cache(cache, k, v, start_pos: int):
    """Write a prefilled [start, start+s) segment into the ring cache."""
    w = cache["k"].shape[1]
    s = k.shape[1]
    if s >= w:
        return {"k": k[:, -w:], "v": v[:, -w:]}
    # assumes start_pos == 0 for prefill (suffix write)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, start_pos % w, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, start_pos % w, 0, 0))
    return {"k": ck, "v": cv}


def attn_decode(params, x, pos, cache, cfg):
    """One-token decode.  x: (b, 1, d); pos: scalar int32 (current index).

    Returns (out (b, 1, d), new_cache).
    """
    b = x.shape[0]
    kv, g, hd = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, cfg.head_dim
    w = cache["k"].shape[1]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, positions, cfg)
    slot = (pos % w).astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                      (zero, slot, zero, zero))
    cv = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                      (zero, slot, zero, zero))
    kpos = cache_positions(pos, w)  # (w,)
    valid = kpos >= 0
    if cfg.window:
        valid = valid & (kpos > pos - cfg.window)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, ck,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, cfg.q_dim).astype(x.dtype)
    out = jnp.einsum("bsq,qd->bsd", o, params["wo"])
    return out, {"k": ck, "v": cv}
