"""Mamba2 SSD (state-space duality) block — chunked, matmul-rich form.

The chunked SSD algorithm (Dao & Gu 2024) maps naturally to the MXU:
intra-chunk terms are (L x L) matmuls, inter-chunk terms a short scan over
chunk states — the TPU-native way to run an attention-free mixer.

Decode keeps O(1) state: (b, heads, head_dim, n_state) + a small causal-
conv tail, which is why mamba2 runs the ``long_500k`` shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def ssd_init(key, cfg, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        # order: [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": L.truncated_normal_init(
            ks[0], (d, 2 * di + 2 * n + h), 1.0, dtype),
        "conv_w": (0.1 * jax.random.normal(
            ks[1], (cfg.conv_width, conv_dim), jnp.float32)).astype(dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "out_proj": L.truncated_normal_init(ks[2], (di, d), 1.0, dtype),
    }


def ssd_axes(cfg, stacked: bool):
    lead = ("layers",) if stacked else ()
    return {
        "in_proj": lead + ("embed", "ssd_in"),
        "conv_w": lead + (None, "state"),
        "a_log": lead + (None,),
        "d_skip": lead + (None,),
        "dt_bias": lead + (None,),
        "norm_scale": lead + (None,),
        "out_proj": lead + ("state", "embed"),
    }


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv.  x: (b, s, c); w: (width, c).

    With a cache (b, width-1, c) of the previous tail, returns the conv
    output and the new tail."""
    width = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = cache
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(width))
    new_tail = xp[:, -(width - 1):]
    return out, new_tail


def _segsum(dA):
    """Stable segment-sum: out[..., l, s] = sum_{s < t <= l} dA[..., t].

    dA: (..., L) -> (..., L, L), lower-triangular meaningful part."""
    l = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # cs_l - cs_s
    return diff


def ssd_scan(x, dt, a, b, c, chunk: int, init_state=None):
    """Chunked SSD.  x: (bt, s, h, p); dt: (bt, s, h); a: (h,) > 0 decay
    rates; b, c: (bt, s, n).  Returns (y (bt, s, h, p), state (bt,h,p,n)).
    """
    bt, s, h, p = x.shape
    n = b.shape[-1]
    ll = min(chunk, s)
    pad = (-s) % ll
    if pad:
        # zero-dt padding is exact: decay exp(0) = 1, contribution dt*x = 0
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    s_orig, s = s, s + pad
    nc = s // ll
    f32 = jnp.float32

    xc = x.reshape(bt, nc, ll, h, p).astype(f32)
    dtc = dt.reshape(bt, nc, ll, h).astype(f32)
    bc = b.reshape(bt, nc, ll, n).astype(f32)
    cc = c.reshape(bt, nc, ll, n).astype(f32)
    da = -a[None, None, None, :] * dtc  # (bt, nc, L, h), negative
    cs = jnp.cumsum(da, axis=2)  # inclusive within chunk

    xdt = xc * dtc[..., None]  # (bt, nc, L, h, p)

    # intra-chunk: y[l] += sum_{s<=l} (C_l . B_s) exp(cs_l - cs_s) xdt[s]
    g = jnp.einsum("bcln,bcsn->bcls", cc, bc)  # (bt, nc, L, L)
    tri = jnp.tril(jnp.ones((ll, ll), bool))
    seg = _segsum(jnp.moveaxis(da, -1, 2))  # (bt, nc, h, L, L)
    # mask BEFORE exp: upper-triangle entries are positive and overflow,
    # and exp-then-mask leaks NaN through the where in the backward pass
    decay = jnp.exp(jnp.where(tri, seg, -1e30))
    m = g[:, :, None] * decay  # (bt, nc, h, L, L)
    y_intra = jnp.einsum("bchls,bcshp->bclhp", m, xdt)

    # chunk states: S_c = sum_s exp(cs_last - cs_s) B_s (x_s dt_s)^T
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)  # (bt, nc, L, h)
    sc = jnp.einsum("bcsn,bcsh,bcshp->bchpn", bc, decay_to_end, xdt)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (bt, nc, h)

    def step(state, inp):
        s_c, dec = inp  # (bt, h, p, n), (bt, h)
        y_state = state  # state entering this chunk
        state = state * dec[..., None, None] + s_c
        return state, y_state

    s0 = (jnp.zeros((bt, h, p, n), f32) if init_state is None
          else init_state.astype(f32))
    state, s_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)  # (bt, nc, h, p, n): state entering c

    # inter-chunk output: y[l] += exp(cs_l) C_l . S_in
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp",
                         cc, jnp.exp(cs), s_in)

    y = (y_intra + y_inter).reshape(bt, s, h, p)
    return y[:, :s_orig], state


def ssd_forward(params, x, cfg, *, init_state=None, conv_cache=None):
    """Full SSD mixer.  x: (b, s, d) -> (b, s, d), plus (state, conv_tail)."""
    b, s, d = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])
    z, xin, bmat, cmat, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, conv_tail = _causal_conv(conv_in, params["conv_w"], conv_cache)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xin, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None])
    a = jnp.exp(params["a_log"])  # (h,) positive rates
    xh = xin.reshape(b, s, h, p)
    y, state = ssd_scan(xh, dt, a, bmat, cmat, cfg.ssm_chunk,
                        init_state=init_state)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    # gated RMSNorm then out-projection (mamba2 ordering)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                   params["norm_scale"])
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    return out, (state, conv_tail)


def ssd_decode(params, x, cache, cfg):
    """One-token decode.  x: (b, 1, d); cache = (state, conv_tail)."""
    state, conv_tail = cache
    b = x.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])
    z, xin, bmat, cmat, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, conv_tail = _causal_conv(conv_in, params["conv_w"], conv_tail)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xin, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None])[:, 0]  # (b, h)
    a = jnp.exp(params["a_log"])
    dec = jnp.exp(-a[None] * dt)  # (b, h)
    xh = xin[:, 0].reshape(b, h, p).astype(jnp.float32)
    xdt = xh * dt[..., None]
    state = state * dec[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", bmat[:, 0].astype(jnp.float32), xdt)
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), state)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                   params["norm_scale"])
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    return out, (state, conv_tail)


def init_ssd_cache(cfg, batch: int, dtype):
    return (jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), jnp.float32),
            jnp.zeros((batch, cfg.conv_width - 1,
                       cfg.d_inner + 2 * cfg.ssm_state), dtype))
