"""Model configuration shared by all assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def pad_vocab(v: int, mult: int = 256) -> int:
    return v + ((-v) % mult)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    vocab_size: int

    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: Optional[int] = None  # sliding-window size; None = full attn

    # mlp
    d_ff: int = 0
    mlp_type: str = "swiglu"  # swiglu | gelu | none
    norm_type: str = "rmsnorm"  # rmsnorm | nonparam_ln

    # layer schedule: one entry per layer within a repeating stage,
    # e.g. ("attn",) for pure transformers, ("rglru", "rglru", "attn")
    # for recurrentgemma, ("ssd",) for mamba2.
    block_pattern: Tuple[str, ...] = ("attn",)

    # MoE
    num_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # RG-LRU (griffin)
    rnn_width: int = 0

    # modality stubs ([vlm]: precomputed patch embeds prepended)
    num_prefix_embeds: int = 0

    # numerics / compilation
    dtype: str = "bfloat16"
    remat: bool = True
    scan_stages: bool = True
    logits_softcap: float = 0.0
    tie_embeddings: bool = False

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:  # ssd
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def stage_pattern(self) -> Tuple[str, ...]:
        return self.block_pattern

    @property
    def num_stages(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def remainder_blocks(self) -> Tuple[str, ...]:
        rem = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    @property
    def sub_quadratic(self) -> bool:
        """True if serving memory/compute does not grow with context
        (SSM / RG-LRU state or bounded attention window)."""
        return all(b != "attn" for b in self.block_pattern) or \
            (self.window is not None)

    def validate(self) -> "ModelConfig":
        def need(ok: bool, what: str):
            if not ok:
                raise ValueError(f"ModelConfig {self.name!r}: {what}")

        if "attn" in self.block_pattern:
            need(self.num_heads * self.head_dim > 0,
                 f"attn blocks need num_heads ({self.num_heads}) and "
                 f"head_dim ({self.head_dim}) > 0")
            need(self.num_heads % max(self.num_kv_heads, 1) == 0,
                 f"num_heads ({self.num_heads}) must divide evenly by "
                 f"num_kv_heads ({self.num_kv_heads})")
        if "ssd" in self.block_pattern:
            need(self.d_inner % self.ssm_head_dim == 0,
                 f"d_inner ({self.d_inner}) must be a multiple of "
                 f"ssm_head_dim ({self.ssm_head_dim})")
        if "rglru" in self.block_pattern:
            need(self.rnn_width > 0,
                 f"rglru blocks need rnn_width > 0 (got {self.rnn_width})")
        if self.num_experts:
            need(self.moe_top_k > 0,
                 f"MoE needs moe_top_k > 0 (got {self.moe_top_k})")
        need(self.num_layers >= len(self.block_pattern),
             f"num_layers ({self.num_layers}) shorter than the block "
             f"pattern ({len(self.block_pattern)})")
        return self


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (arch x shape) cell: what to lower and at what size."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
