"""Serving launcher (CPU-sized with --smoke; full config lowers via
launch/dryrun.py decode shapes).

  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
      --smoke --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as CFG
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (CFG.get_smoke_config(args.arch) if args.smoke
           else CFG.get_config(args.arch))
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, max_len=args.prompt_len + args.gen,
                      temperature=args.temperature)
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.num_prefix_embeds:
        batch["embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.num_prefix_embeds, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    t0 = time.perf_counter()
    toks, _ = eng.generate(batch, steps=args.gen)
    toks.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(toks[:, :16]))


if __name__ == "__main__":
    main()
