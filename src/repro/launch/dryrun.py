import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver

  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. resolves the arch's logical->mesh sharding rules,
  3. lowers the appropriate step (train_step / prefill_step / serve_step)
     from ShapeDtypeStruct stand-ins — no arrays are ever allocated,
  4. ``compile()``s it (proving the SPMD partitioning is coherent),
  5. records memory_analysis / cost_analysis / per-kind collective bytes
     (parsed from the optimized HLO) into a JSON blob for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs as CFG
from repro.dist.sharding import activation_hints, arch_rules, tree_shardings
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.config import SHAPES
from repro.optim.muon import MuonConfig
from repro.train.step import make_train_step, state_axes_for_params

_DTYPE_BYTES = {"f8": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
                "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|f8\w*|s8|u8|s16|u16|s32|u32|s64"
                       r"|u64|pred|c64|c128)\[([0-9,]*)\]")


def _bytes_of(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype.split("E")[0], 4)


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _group_size(rhs: str) -> int:
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:
        return int(m.group(2))  # [ngroups, group_size]<=[N]
    m = _GROUPS_LIST_RE.search(rhs)
    if m:
        body = m.group(1).strip()
        return len(body.split(",")) if body else 1
    return 1


def _wire_factor(kind: str, gs: int) -> float:
    """Ring-algorithm wire bytes per participating device, as a multiple
    of the (per-device) operand bytes."""
    if gs <= 1:
        return 0.0
    if kind == "all-gather":
        return gs - 1.0
    if kind == "all-reduce":
        return 2.0 * (gs - 1.0) / gs
    if kind in ("reduce-scatter", "all-to-all"):
        return (gs - 1.0) / gs
    return 1.0  # collective-permute


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind operand bytes and estimated ring wire-bytes of every
    collective op in optimized (partitioned, per-device) HLO text."""
    out = {k: {"count": 0, "bytes": 0, "wire_bytes": 0.0}
           for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(?:-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rhs:
            continue  # counted at -start
        # operand types appear inline inside the call parens
        paren = rhs.find("(")
        args = rhs[paren:]
        shapes = _SHAPE_RE.findall(args)
        if not shapes:  # fall back to the result type
            shapes = _SHAPE_RE.findall(rhs[:paren])
        nbytes = sum(_bytes_of(d, dims) for d, dims in shapes)
        gs = _group_size(rhs)
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
        out[kind]["wire_bytes"] += nbytes * _wire_factor(kind, gs)
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    out["total_wire_bytes"] = sum(v["wire_bytes"] for v in out.values()
                                  if isinstance(v, dict))
    return out


def _sds_tree(abstract, shardings):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               stages_override=None, optimized: bool = False):
    """Build and lower one cell.  Returns (lowered, meta).

    ``stages_override``: lower a reduced variant with that many scanned
    stages (same remainder) — used to extrapolate scan-body costs, since
    XLA's cost analysis counts a scan body once rather than x trip-count.
    """
    cfg = CFG.get_config(arch)
    if stages_override is not None:
        pat = len(cfg.block_pattern)
        rem = cfg.num_layers % pat
        cfg = dataclasses.replace(
            cfg, num_layers=pat * stages_override + rem)
    shape = SHAPES[shape_name]
    skip = CFG.registry.cell_supported(cfg, shape)
    if skip:
        return None, {"skip": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = arch_rules(cfg, mesh, shape)

    import contextlib
    hints_ctx = (activation_hints(rules) if optimized
                 else contextlib.nullcontext())

    if shape.kind == "train":
        muon = MuonConfig(polar_dtype="bfloat16" if optimized
                          else "float32")
        init_fn, train_step = make_train_step(cfg, muon)
        abstract_state = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
        axes = state_axes_for_params(cfg, abstract_state.params)
        st_sh = tree_shardings(mesh, rules, axes)
        state_sds = _sds_tree(abstract_state, st_sh)
        batch_abs = CFG.input_specs(cfg, shape, abstract=True)
        batch_axes = {"tokens": ("batch", None)}
        if "embeds" in batch_abs:
            batch_axes["embeds"] = ("batch", None, None)
        batch_sds = _sds_tree(batch_abs,
                              tree_shardings(mesh, rules, batch_axes))
        with mesh, hints_ctx:
            lowered = jax.jit(train_step).lower(state_sds, batch_sds)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return M.prefill(params, batch, cfg, max_len=shape.seq_len)

        abstract_params = jax.eval_shape(
            lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
        p_sh = tree_shardings(mesh, rules, M.params_axes(cfg))
        params_sds = _sds_tree(abstract_params, p_sh)
        batch_abs = CFG.input_specs(cfg, shape, abstract=True)
        batch_axes = {"tokens": ("batch", None)}
        if "embeds" in batch_abs:
            batch_axes["embeds"] = ("batch", None, None)
        batch_sds = _sds_tree(batch_abs,
                              tree_shardings(mesh, rules, batch_axes))
        with mesh, hints_ctx:
            lowered = jax.jit(prefill_step).lower(params_sds, batch_sds)
    else:  # decode
        def serve_step(params, tokens, caches):
            return M.decode_step(params, tokens, caches, cfg)

        abstract_params = jax.eval_shape(
            lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))
        p_sh = tree_shardings(mesh, rules, M.params_axes(cfg))
        params_sds = _sds_tree(abstract_params, p_sh)
        abstract_caches = jax.eval_shape(
            lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len))
        c_sh = tree_shardings(mesh, rules, M.caches_axes(cfg))
        caches_sds = _sds_tree(abstract_caches, c_sh)
        tok_sds = _sds_tree(
            {"t": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)},
            {"t": tree_shardings(mesh, rules, {"t": ("batch", None)})["t"]},
        )["t"]
        with mesh, hints_ctx:
            lowered = jax.jit(serve_step).lower(params_sds, tok_sds,
                                                caches_sds)
    meta = {"mesh": "2x16x16" if multi_pod else "16x16",
            "devices": 512 if multi_pod else 256}
    return lowered, meta


def _cell_costs(lowered) -> dict:
    """compile + extract {flops, bytes, collectives} for one lowering."""
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0))}
    try:
        out["collectives"] = collective_bytes(compiled.as_text())
    except Exception:
        out["collectives"] = None
    return out


def _extrapolate(v1: dict, v2: dict, stages: int) -> dict:
    """linear-in-stages extrapolation from 1- and 2-stage variants."""
    def lin(a, b):
        return a + (stages - 1) * (b - a)

    out = {"flops": lin(v1["flops"], v2["flops"]),
           "bytes": lin(v1["bytes"], v2["bytes"])}
    c1, c2 = v1.get("collectives"), v2.get("collectives")
    if c1 and c2:
        coll = {}
        for k in _COLLECTIVES:
            coll[k] = {
                "count": int(lin(c1[k]["count"], c2[k]["count"])),
                "bytes": int(lin(c1[k]["bytes"], c2[k]["bytes"])),
                "wire_bytes": lin(c1[k]["wire_bytes"], c2[k]["wire_bytes"]),
            }
        coll["total_bytes"] = int(lin(c1["total_bytes"], c2["total_bytes"]))
        coll["total_wire_bytes"] = lin(c1["total_wire_bytes"],
                                       c2["total_wire_bytes"])
        out["collectives"] = coll
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str, hlo_text: bool = True,
             optimized: bool = False) -> dict:
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "optimized": optimized,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod,
                                   optimized=optimized)
        rec.update(meta)
        if lowered is None:
            rec["status"] = "skip"
            os.makedirs(out_dir, exist_ok=True)
            fn = (f"{arch}__{shape_name}__"
                  f"{rec['mesh'].replace('x', '_')}.json")
            with open(os.path.join(out_dir, fn), "w") as f:
                json.dump(rec, f, indent=1)
            return rec
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        rec["status"] = "ok"
        rec["lower_s"] = round(t_lower - t0, 1)
        rec["compile_s"] = round(t_compile - t_lower, 1)
        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(mem, k))
                for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                          "output_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:  # CPU backend may not implement it
            rec["memory"] = {"error": str(e)[:200]}
        try:
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            rec["cost"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float))
                           and k in ("flops", "bytes accessed",
                                     "transcendentals", "optimal_seconds")}
        except Exception as e:
            rec["cost"] = {"error": str(e)[:200]}
        if hlo_text:
            try:
                txt = compiled.as_text()
                rec["collectives"] = collective_bytes(txt)
                rec["hlo_bytes"] = len(txt)
                del txt
            except Exception as e:
                rec["collectives"] = {"error": str(e)[:200]}
        # XLA costs a lax.scan body once, not x trips: extrapolate the
        # scanned-stage costs from 1- and 2-stage lowerings (linear).
        try:
            cfg = CFG.get_config(arch)
            stages = cfg.num_stages
            if stages > 1:
                l1, _ = lower_cell(arch, shape_name, multi_pod,
                                   stages_override=1, optimized=optimized)
                l2, _ = lower_cell(arch, shape_name, multi_pod,
                                   stages_override=2, optimized=optimized)
                v1 = _cell_costs(l1)
                v2 = _cell_costs(l2)
                rec["cost_extrapolated"] = _extrapolate(v1, v2, stages)
                rec["scan_correction"] = {
                    "stages": stages, "v1_flops": v1["flops"],
                    "v2_flops": v2["flops"]}
            else:
                rec["cost_extrapolated"] = {
                    "flops": rec["cost"].get("flops"),
                    "bytes": rec["cost"].get("bytes accessed"),
                    "collectives": rec.get("collectives")}
        except Exception as e:
            rec["cost_extrapolated"] = {"error": str(e)[:300]}
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = "".join(
            traceback.format_exception_only(type(e), e))[-2000:]
        rec["trace"] = traceback.format_exc()[-4000:]
    finally:
        rec["total_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    suffix = "__opt" if optimized else ""
    fn = (f"{arch}__{shape_name}__"
          f"{rec['mesh'].replace('x', '_')}{suffix}.json")
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="lower with activation-sharding hints (§Perf)")
    args = ap.parse_args()

    archs = CFG.list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                fn = os.path.join(
                    args.out, f"{arch}__{shape}__"
                    f"{'2_16_16' if mp else '16_16'}"
                    f"{'__opt' if args.optimized else ''}.json")
                if args.skip_existing and os.path.exists(fn):
                    with open(fn) as f:
                        if json.load(f).get("status") in ("ok", "skip"):
                            print(f"[dryrun] cached {fn}")
                            continue
                rec = run_cell(arch, shape, mp, args.out,
                               optimized=args.optimized)
                summary = {k: rec.get(k) for k in
                           ("arch", "shape", "mesh", "status", "compile_s")}
                if rec.get("status") == "fail":
                    summary["error"] = rec.get("error", "")[:300]
                print(f"[dryrun] {summary}", flush=True)


if __name__ == "__main__":
    main()
