"""SVD-serving launcher: synthetic open-loop workload against
:class:`repro.serve.SvdService`.

Open-loop means arrivals come from a Poisson clock, not from completion
callbacks — the stream does not slow down when the service falls behind,
so measured latency includes real queueing delay (the honest serving
metric; a closed loop would hide overload).  Shapes and accuracy modes
are drawn per-request from the configured pools, so the stream is
heterogeneous the way the bucketed plan pool is designed for.

  PYTHONPATH=src python -m repro.launch.svd_serve --requests 64 \
      --rate 200 --batch 4 --shapes 96x64,40x100,120x80

``benchmarks/svd_serve.py`` drives :func:`run_workload` directly for the
batch-size x arrival-rate sweep behind ``BENCH_serve.json``.
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Dict, List, Sequence, Tuple

if __name__ == "__main__":
    # standalone launch: f64 request dtypes need x64 set before jax loads
    os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np

import jax.numpy as jnp

from repro.serve import ServiceConfig, SvdService


def synth_matrix(m: int, n: int, kappa: float = 1e3, seed: int = 0,
                 dtype=jnp.float64):
    """Geometric-spectrum test matrix (exact kappa_2, Haar-ish U/V)."""
    rng = np.random.default_rng(seed)
    k = min(m, n)
    u, _ = np.linalg.qr(rng.standard_normal((m, k)))
    v, _ = np.linalg.qr(rng.standard_normal((n, k)))
    s = np.geomspace(1.0, 1.0 / kappa, k)
    return jnp.asarray((u * s) @ v.T, dtype=dtype)


def run_workload(service: SvdService,
                 shapes: Sequence[Tuple[int, int]],
                 modes: Sequence[str] = ("standard",),
                 requests: int = 64,
                 rate: float = 200.0,
                 kappa: float = 1e3,
                 dtype=jnp.float64,
                 seed: int = 0,
                 warm: bool = True) -> Dict[str, float]:
    """Drive one open-loop run; returns the serving record.

    Matrices are synthesized (and transferred) before the clock starts,
    arrival times are a Poisson process at ``rate``/s, and the driver
    loop is the service's cooperative cadence: submit everything whose
    arrival time has passed, ``poll()``, sleep to the next arrival.
    Latency per request is submit-to-ready as stamped by the service's
    non-blocking completion sweep.
    """
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(requests):
        m, n = shapes[int(rng.integers(len(shapes)))]
        mode = modes[int(rng.integers(len(modes)))]
        reqs.append((synth_matrix(m, n, kappa, seed=i, dtype=dtype), mode))
    if warm:
        service.warmup(shapes, modes=modes,
                       dtypes=(jnp.dtype(dtype).name,))
    arrivals = np.cumsum(rng.exponential(1.0 / rate, requests))

    futs: List = []
    i = 0
    t0 = time.perf_counter()
    while i < len(reqs):
        now = time.perf_counter() - t0
        while i < len(reqs) and arrivals[i] <= now:
            a, mode = reqs[i]
            futs.append(service.submit(a, mode))
            i += 1
        service.poll()
        if i < len(reqs):
            ahead = arrivals[i] - (time.perf_counter() - t0)
            if ahead > 0:
                time.sleep(min(ahead, 1e-3))
    service.flush()
    wall = time.perf_counter() - t0

    lats = np.asarray([f.latency for f in futs], float)
    ok = sum(1 for f in futs if f.exception() is None)
    stats = service.stats()
    return {
        "requests": requests,
        "rate_req_s": rate,
        "wall_s": wall,
        "solves_per_s": requests / wall,
        "p50_ms": float(np.percentile(lats, 50) * 1e3),
        "p99_ms": float(np.percentile(lats, 99) * 1e3),
        "pad_waste": stats["pad_waste"],
        "slot_fill": stats["slot_fill"],
        "plan_cache_hit_rate": stats["plan_cache_hit_rate"],
        "retraces": stats["retraces"],
        "batches": stats["batches"],
        # resilience counters (PR 9): a fault-free run reports zeros
        # and ok == requests; a fault-injected run shows the recovery
        # paths the stream exercised
        "ok": ok,
        "verify": service.config.verify,
        "retries": stats["retries"],
        "health_failures": stats["health_failures"],
        "quarantined": stats["quarantined"],
        "deadline_expired": stats["deadline_expired"],
        "dispatch_errors": stats["dispatch_errors"],
    }


def _parse_shapes(text: str) -> List[Tuple[int, int]]:
    shapes = []
    for part in text.split(","):
        m, _, n = part.strip().partition("x")
        shapes.append((int(m), int(n)))
    return shapes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="open-loop arrival rate, requests/s")
    ap.add_argument("--batch", type=int, default=4,
                    help="micro-batch slot count per bucket")
    ap.add_argument("--max-wait", type=float, default=0.005,
                    help="partial-batch head-of-line age bound, s")
    ap.add_argument("--shapes", default="96x64,120x80,40x100",
                    help="comma-separated MxN request shape pool")
    ap.add_argument("--modes", default="standard",
                    help="comma-separated accuracy-mode pool")
    ap.add_argument("--kappa", type=float, default=1e3)
    ap.add_argument("--dtype", default="float64")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    service = SvdService(ServiceConfig(batch_size=args.batch,
                                       max_wait=args.max_wait))
    rec = run_workload(service, _parse_shapes(args.shapes),
                       modes=tuple(args.modes.split(",")),
                       requests=args.requests, rate=args.rate,
                       kappa=args.kappa, dtype=jnp.dtype(args.dtype),
                       seed=args.seed)
    print(f"[svd_serve] {rec['requests']} requests at "
          f"{rec['rate_req_s']:.0f}/s open-loop -> "
          f"{rec['solves_per_s']:.1f} solves/s, "
          f"p50 {rec['p50_ms']:.1f} ms, p99 {rec['p99_ms']:.1f} ms")
    print(f"[svd_serve] pad waste {rec['pad_waste']:.0%}, slot fill "
          f"{rec['slot_fill']:.0%}, plan-cache hit rate "
          f"{rec['plan_cache_hit_rate']:.0%}, retraces {rec['retraces']}")


if __name__ == "__main__":
    main()
