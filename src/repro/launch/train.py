"""Training launcher.

Real runs on this container are CPU-sized (--smoke swaps in the reduced
config); the same driver lowers the full config on the production mesh
(that path is exercised via launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse

import jax

from repro import configs as CFG
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM
from repro.optim.muon import MuonConfig
from repro.train.loop import TrainLoop
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--method", default="zolo",
                    choices=["zolo", "qdwh", "ns5"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (CFG.get_smoke_config(args.arch) if args.smoke
           else CFG.get_config(args.arch))
    muon = MuonConfig(lr=args.lr, method=args.method)
    init_fn, step_fn = make_train_step(cfg, muon, total_steps=args.steps)

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                       num_prefix_embeds=cfg.num_prefix_embeds,
                       d_model=cfg.d_model, dtype=cfg.dtype, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    loop = TrainLoop(step_fn, data, ckpt=ckpt, ckpt_every=args.ckpt_every,
                     log_path=args.log,
                     tokens_per_step=args.batch * args.seq)
    state = loop.resume_or_init(init_fn, jax.random.PRNGKey(args.seed))
    state = loop.run(state, args.steps)
    print(f"[train] finished at step {int(state.step)}")


if __name__ == "__main__":
    main()
