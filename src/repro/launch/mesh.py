"""Production meshes.  Importing this module never touches jax device
state — mesh construction happens inside the functions."""

from __future__ import annotations

import math

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips of v5e) or 2x16x16 multi-pod mesh.

    The 'pod' axis is pure data parallelism (gradient all-reduce over DCI);
    'data' hosts DP/FSDP, 'model' hosts TP/EP.  Uses the first prod(shape)
    devices so it works in the 512-device dry-run container for both
    variants."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) == need:
        return jax.make_mesh(shape, axes)
    if len(devices) < need:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {need} devices; only "
            f"{len(devices)} available")
    arr = np.asarray(devices[:need]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for tests on whatever devices exist."""
    import jax

    devices = jax.devices()[: data * model]
    arr = np.asarray(devices).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))
