"""Zolotarev and QDWH iteration coefficients (paper §2.1-§2.2).

Two backends:

* ``zolo_coeffs`` — JAX, jittable: coefficients computed *in-graph* from a
  runtime lower bound ``l`` (so condition estimates feeding a compiled
  train step work).  Uses :mod:`repro.core.elliptic`.
* ``zolo_schedule_np`` — numpy/scipy float64 at trace time: a *static*
  schedule of per-iteration coefficients for a fixed ``l0``.  This is what
  the ZoloMuon optimizer embeds (constants in the compiled graph, like the
  fixed Newton-Schulz coefficients in standard Muon).

Notation follows the paper: for order ``r`` and lower bound ``l``,

    c_i  = l^2 sn^2(i K'/(2r+1); l') / cn^2(...)      i = 1..2r   (eq. 7)
    Mhat = prod_j (1 + c_{2j-1}) / (1 + c_{2j})                    (eq. 8)
    a_j  = -prod_k (c_{2j-1} - c_{2k}) / prod_{k!=j} (c_{2j-1} - c_{2k-1})
                                                                   (eq. 10)
    l_next = Mhat * l * prod_j (l^2 + c_{2j}) / (l^2 + c_{2j-1})

(the paper's eq. for the l-update has a typo — ``l + c_{2j}`` — the correct
update is the scaled function evaluated at l, i.e. ``l^2 + c_{2j}``; this
matches [Nakatsukasa-Freund 2016] and is verified in tests against the
equioscillation property.)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elliptic

try:  # scipy is available in this environment; keep a guard for portability
    from scipy import special as _scipy_special
except ImportError:  # pragma: no cover
    _scipy_special = None

# Machine-epsilon targets used for convergence tests (paper: 1e-15 band).
EPS64 = 1.1e-16
MAX_R = 8


# ---------------------------------------------------------------------------
# JAX backend
# ---------------------------------------------------------------------------


def zolo_coeffs(l, r: int):
    """Zolotarev coefficients for order ``r`` and lower bound ``l`` (JAX).

    Returns ``(c, a, mhat)`` with ``c`` shaped (2r,) (``c[i-1]`` is the
    paper's ``c_i``), ``a`` shaped (r,), and scalar ``mhat``.
    ``r`` must be a static python int.
    """
    l = jnp.asarray(l)
    mc = l * l
    kp = elliptic.ellipk_mc(mc)
    i = jnp.arange(1, 2 * r + 1, dtype=l.dtype)
    u = i * kp / (2 * r + 1)
    sn, cn, _ = elliptic.ellipj_mc(u, mc)
    c = mc * (sn * sn) / (cn * cn)

    c_even = c[1::2]  # c_{2j},   j=1..r
    c_odd = c[0::2]  # c_{2j-1}, j=1..r
    mhat = jnp.prod((1.0 + c_odd) / (1.0 + c_even))

    # a_j via the residue formula; the k == j term in the denominator
    # product is masked to 1.
    diff_even = c_odd[:, None] - c_even[None, :]  # (j, k): c_{2j-1}-c_{2k}
    diff_odd = c_odd[:, None] - c_odd[None, :]  # (j, k): c_{2j-1}-c_{2k-1}
    eye = jnp.eye(r, dtype=l.dtype)
    a = -jnp.prod(diff_even, axis=1) / jnp.prod(diff_odd + eye, axis=1)
    return c, a, mhat


def zolo_l_update(l, c, mhat):
    """Map the lower bound through the scaled Zolotarev function."""
    l = jnp.asarray(l)
    c_even = c[1::2]
    c_odd = c[0::2]
    l2 = l * l
    return mhat * l * jnp.prod((l2 + c_even) / (l2 + c_odd))


def zolo_fn_scalar(x, c, a, mhat):
    """Evaluate hat-Z_{2r+1}(x; l) in partial-fraction form (eq. 9/11)."""
    x = jnp.asarray(x)
    c_odd = c[0::2]
    terms = a[..., :] / (x[..., None] ** 2 + c_odd)
    return mhat * x * (1.0 + jnp.sum(terms, axis=-1))


def zolo_fn_product(x, c, mhat):
    """Evaluate hat-Z_{2r+1}(x; l) in product form (eq. 8) — test oracle."""
    x = jnp.asarray(x)
    c_even = c[1::2]
    c_odd = c[0::2]
    num = x[..., None] ** 2 + c_even
    den = x[..., None] ** 2 + c_odd
    return mhat * x * jnp.prod(num / den, axis=-1)


# ---------------------------------------------------------------------------
# numpy/scipy backend (trace-time static schedules)
# ---------------------------------------------------------------------------


def _ellipj_mc_np(u, mc):
    if _scipy_special is not None and mc > 1e-14:
        sn, cn, dn, _ = _scipy_special.ellipj(np.asarray(u), 1.0 - mc)
        return sn, cn, dn
    try:
        # Extreme regime (kappa > 1e7): f64 Landen loses ~8 digits, so use
        # arbitrary precision when available (trace-time only, tiny inputs).
        import mpmath

        with mpmath.workdps(40):
            m = mpmath.mpf(1) - mpmath.mpf(float(mc))
            sn = np.array([float(mpmath.ellipfun("sn", float(x), m=m))
                           for x in np.atleast_1d(u)])
            cn = np.array([float(mpmath.ellipfun("cn", float(x), m=m))
                           for x in np.atleast_1d(u)])
            dn = np.array([float(mpmath.ellipfun("dn", float(x), m=m))
                           for x in np.atleast_1d(u)])
        return sn, cn, dn
    except ImportError:  # pragma: no cover
        sn, cn, dn = elliptic.ellipj_mc(jnp.float64(u), jnp.float64(mc))
        return np.asarray(sn), np.asarray(cn), np.asarray(dn)


def _ellipk_mc_np(mc):
    if _scipy_special is not None:
        return float(_scipy_special.ellipkm1(mc))
    return float(elliptic.ellipk_mc(jnp.float64(mc)))


def zolo_coeffs_np(l: float, r: int):
    """float64 numpy version of :func:`zolo_coeffs` (trace-time)."""
    l = float(l)
    mc = l * l
    kp = _ellipk_mc_np(mc)
    i = np.arange(1, 2 * r + 1, dtype=np.float64)
    u = i * kp / (2 * r + 1)
    sn, cn, _ = _ellipj_mc_np(u, mc)
    c = mc * sn**2 / cn**2
    c_even = c[1::2]
    c_odd = c[0::2]
    mhat = float(np.prod((1.0 + c_odd) / (1.0 + c_even)))
    a = np.empty(r, dtype=np.float64)
    for j in range(r):
        num = np.prod(c_odd[j] - c_even)
        den = np.prod(np.delete(c_odd[j] - c_odd, j))
        a[j] = -num / den
    return c, a, mhat


def zolo_l_update_np(l: float, c: np.ndarray, mhat: float) -> float:
    c_even = c[1::2]
    c_odd = c[0::2]
    l2 = l * l
    return float(mhat * l * np.prod((l2 + c_even) / (l2 + c_odd)))


@dataclasses.dataclass(frozen=True)
class ZoloIteration:
    """Static coefficients for one Zolo-PD iteration."""

    c: tuple  # (2r,)
    a: tuple  # (r,)
    mhat: float
    l_before: float
    l_after: float

    @property
    def r(self) -> int:
        return len(self.a)


def zolo_schedule_np(l0: float, r: int, max_iters: int = 8,
                     tol: float = 1.0 - 1e-15) -> list[ZoloIteration]:
    """Static per-iteration coefficient schedule until 1 - l <= 1 - tol."""
    sched = []
    l = float(l0)
    for _ in range(max_iters):
        c, a, mhat = zolo_coeffs_np(l, r)
        l_next = zolo_l_update_np(l, c, mhat)
        sched.append(ZoloIteration(tuple(c), tuple(a), mhat, l, l_next))
        l = l_next
        if l >= tol:
            break
    return sched


@functools.lru_cache(maxsize=None)
def zolo_iter_count(kappa: float, r: int, tol: float = 1e-15,
                    max_iters: int = 64) -> int:
    """Smallest k with hat-Z^k([1/kappa, 1]) inside [1 - tol, 1].

    This regenerates the paper's Table 1 from first principles (scalar
    recursion on the interval lower bound).
    """
    l = 1.0 / float(kappa)
    for k in range(1, max_iters + 1):
        c, _, mhat = zolo_coeffs_np(l, r)
        l = zolo_l_update_np(l, c, mhat)
        if 1.0 - l <= tol:
            return k
    return max_iters


def choose_r(kappa: float, max_groups: int = 3, tol: float = 1e-15) -> int:
    """Paper §3.2 policy: prefer small r (2 or 3); only grow r beyond that
    when it actually removes an iteration and resources allow (Table 1)."""
    kappa = max(float(kappa), 1.0 + 1e-12)
    best_r, best_iters = 1, zolo_iter_count(kappa, 1, tol)
    for r in range(2, min(max_groups, MAX_R) + 1):
        it = zolo_iter_count(kappa, r, tol)
        if it < best_iters:
            best_r, best_iters = r, it
    return best_r


# ---------------------------------------------------------------------------
# QDWH dynamic coefficients (paper eq. 2/3; Nakatsukasa-Bai-Gygi 2010)
# ---------------------------------------------------------------------------


def qdwh_coeffs(l):
    """Dynamically-weighted Halley coefficients (a, b, c) for bound ``l``.

    JAX-friendly; ``l`` may be a traced scalar.
    """
    l = jnp.asarray(l)
    l2 = l * l
    d = jnp.cbrt(4.0 * (1.0 - l2) / (l2 * l2))
    a = jnp.sqrt(1.0 + d) + 0.5 * jnp.sqrt(
        8.0 - 4.0 * d + 8.0 * (2.0 - l2) / (l2 * jnp.sqrt(1.0 + d))
    )
    b = (a - 1.0) ** 2 / 4.0
    c = a + b - 1.0
    return a, b, c


def qdwh_l_update(l, a, b, c):
    l = jnp.asarray(l)
    return l * (a + b * l * l) / (1.0 + c * l * l)


def qdwh_coeffs_np(l: float):
    l2 = l * l
    d = (4.0 * (1.0 - l2) / (l2 * l2)) ** (1.0 / 3.0)
    a = np.sqrt(1.0 + d) + 0.5 * np.sqrt(
        8.0 - 4.0 * d + 8.0 * (2.0 - l2) / (l2 * np.sqrt(1.0 + d))
    )
    b = (a - 1.0) ** 2 / 4.0
    c = a + b - 1.0
    return float(a), float(b), float(c)


def qdwh_schedule_np(l0: float, max_iters: int = 20,
                     tol: float = 1.0 - 1e-15) -> list[tuple]:
    """Static (a, b, c, l) schedule for QDWH from initial bound l0."""
    sched = []
    l = float(l0)
    for _ in range(max_iters):
        a, b, c = qdwh_coeffs_np(l)
        sched.append((a, b, c, l))
        l = float(l * (a + b * l * l) / (1.0 + c * l * l))
        if l >= tol:
            break
    return sched


def qdwh_iter_count(kappa: float, tol: float = 1e-15) -> int:
    return len(qdwh_schedule_np(1.0 / float(kappa), tol=1.0 - tol))
