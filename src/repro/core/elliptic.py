"""Jacobi elliptic functions and complete elliptic integrals in JAX.

The Zolotarev coefficients (paper eq. 7) need

    K' = K(m = 1 - l^2)            (complete elliptic integral)
    sn(u; l'), cn(u; l')           (Jacobi elliptic functions, modulus l')

For ill-conditioned problems ``l`` is tiny, so ``m = 1 - l^2`` suffers
catastrophic cancellation.  All entry points therefore take the
*complementary* parameter ``mc = l^2`` directly and never form ``1 - l^2``.

Implementation: AGM for K (a dozen quadratically-convergent steps) and the
descending Gauss/Landen transformation for sn/cn/dn (Abramowitz & Stegun
16.4, the classical ``sncndn`` recursion).  Everything is a fixed-length
unrolled loop so it jits, vmaps and differentiates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Number of AGM / Landen levels.  AGM converges quadratically; 12 levels
# give ~1e-16 for mc >= 1e-32 (i.e. condition numbers up to 1e16).
_AGM_LEVELS = 12


def _agm_sequence(mc):
    """AGM sequence for modulus k' = sqrt(mc).

    Returns (a_list, c_list) with a_n the arithmetic means and
    c_n = (a_{n-1} - b_{n-1}) / 2 (c_0 = k = sqrt(1 - mc)).
    """
    mc = jnp.asarray(mc)
    one = jnp.ones_like(mc)
    a = one
    b = jnp.sqrt(mc)
    # c_0 = k (the modulus); kept for the phi recursion convention.
    c = jnp.sqrt(jnp.maximum(one - mc, 0.0))
    a_hist = [a]
    c_hist = [c]
    for _ in range(_AGM_LEVELS):
        a_next = 0.5 * (a + b)
        c_next = 0.5 * (a - b)
        b = jnp.sqrt(jnp.maximum(a * b, 0.0))
        a = a_next
        a_hist.append(a)
        c_hist.append(c_next)
    return a_hist, c_hist


def ellipk_mc(mc):
    """Complete elliptic integral K(m) with m = 1 - mc, from the
    complementary parameter mc.  K' of modulus l is ``ellipk_mc(l**2)``."""
    a_hist, _ = _agm_sequence(mc)
    return jnp.pi / (2.0 * a_hist[-1])


def ellipj_mc(u, mc):
    """Jacobi elliptic sn(u|m), cn(u|m), dn(u|m) with m = 1 - mc.

    Uses the descending Landen/Gauss transformation.  Accurate for
    mc in (0, 1]; for mc -> 0 (m -> 1) the functions degenerate to
    tanh/sech which the AGM handles as long as mc >= ~1e-32 in f64.
    """
    u = jnp.asarray(u)
    mc = jnp.asarray(mc)
    a_hist, c_hist = _agm_sequence(mc)
    n = _AGM_LEVELS
    phi = (2.0 ** n) * a_hist[n] * u
    for i in range(n, 0, -1):
        t = (c_hist[i] / a_hist[i]) * jnp.sin(phi)
        t = jnp.clip(t, -1.0, 1.0)
        phi = 0.5 * (phi + jnp.arcsin(t))
    sn = jnp.sin(phi)
    cn = jnp.cos(phi)
    m = 1.0 - mc
    dn = jnp.sqrt(jnp.maximum(1.0 - m * sn * sn, 0.0))
    return sn, cn, dn


def ellipk(m):
    """K(m) from the parameter m (convenience; prefer ellipk_mc)."""
    return ellipk_mc(1.0 - jnp.asarray(m))


@jax.jit
def _kp_of_l(l):
    return ellipk_mc(l * l)


def kprime(l):
    """K'(l) = K(1 - l^2): the complete integral of the complementary
    modulus l' = sqrt(1 - l^2), as used in the Zolotarev coefficients."""
    return _kp_of_l(jnp.asarray(l))
