"""Kernel-backed Zolo-PD: the ``zolo_pallas`` / ``zolo_pallas_dynamic``
registry backends.

Binds both schedule sources of the one Zolotarev engine in
:mod:`repro.core.zolo` — the static trace-time schedule
(:func:`zolo_pd_pallas`) and the dynamic in-graph-coefficient loop
(:func:`zolo_pd_pallas_dynamic`) — to a
:class:`repro.core.zolo.ZoloOps` bundle whose two hot loops are
hand-tiled TPU kernels:

* ``repro.kernels.ops.gram``         — fused shifted Gram
  ``G = X^T X + c I`` (MXU tiles, f32 accumulation; Alg. 1 step 4d /
  Alg. 3 step 4c hot spot).
* ``repro.kernels.ops.polar_update`` — fused r-term combine
  ``X2 = mhat (X + sum_j a_j T_j)`` (the DGSUM2D role; one HBM pass
  over the r+1 arrays instead of chained read-modify-writes).

On TPU the kernels compile; on any other backend they run in Pallas
interpret mode (the kernel body executes in Python) so the backend stays
testable on CPU — numerically correct but slow, which the registered
cost model in :mod:`repro.core.svd` reflects, keeping ``method="auto"``
from picking it off-TPU.

Tile sizes thread from ``SvdConfig.extra``::

    SvdConfig(method="zolo_pallas", l0=1e-3,
              extra=(("bn", 128), ("bk", 256)))

This module is the pattern every future Pallas hot spot follows: wrap
the kernel in a ``ZoloOps`` field (or a new bundle slot, e.g. the
CholeskyQR2 second-pass Gram or the grouped combine), inject it into the
shared driver, register the result as its own backend.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import zolo as _zolo
from repro.kernels import ops as _kops


def pallas_zolo_ops(*, bn: int = 256, bk: int = 512, bm: int = 256,
                    use_pallas: bool = True) -> _zolo.ZoloOps:
    """A :class:`repro.core.zolo.ZoloOps` bundle backed by the Pallas
    kernels.

    ``bn``/``bk`` tile the Gram kernel ((bk, bn) A-tiles, (bn, bn)
    output accumulator), ``bm``/``bn`` tile the combine; the wrappers in
    :mod:`repro.kernels.ops` shrink tiles to fit and pad non-multiple
    shapes.  ``use_pallas=False`` routes both ops to the jnp oracles —
    the ablation path the benchmarks compare against.

    The kernels are 2-D; batched inputs (reached via ``vmap`` in
    ``SvdPlan.svd_batched``) map over their leading axes outside this
    bundle, so each call still sees one (m, n) problem.  An explicitly
    *stacked* r-term operand — the CholeskyQR2 second-pass Gram over
    (r, m, n) Q factors — unrolls the 2-D kernel over its static leading
    axis (r is small, 2..8), so that hot spot runs on the kernel too
    instead of falling back to a batched einsum.  f64 inputs are
    accepted but accumulate in f32 (the kernels' MXU dtype policy);
    callers needing full f64 stay on the default jnp ops.
    """

    def gram(x, c=0.0):
        if x.ndim == 3 and x.shape[0] <= 8:
            # static r-stack (term batch; Table 1 keeps r <= 8): unroll
            # the 2-D kernel.  Larger leading dims are data batches, not
            # term stacks — unrolling those would bloat the trace, so
            # they stay on the batched jnp path below.
            return jnp.stack([
                _kops.gram(x[j], c, bn=bn, bk=bk, use_pallas=use_pallas)
                for j in range(x.shape[0])])
        if x.ndim != 2:
            return _zolo._gram(x, c)  # data batches stay on jnp
        return _kops.gram(x, c, bn=bn, bk=bk, use_pallas=use_pallas)

    def polar_update(x, t, a, mhat):
        if x.ndim != 2:
            return _zolo._polar_update(x, t, a, mhat)
        return _kops.polar_update(x, t, a, mhat, bm=bm, bn=bn,
                                  use_pallas=use_pallas)

    # single address space: a replicated operand's Gram is the same op
    return _zolo.ZoloOps(gram=gram, polar_update=polar_update,
                         gram_local=gram)


def zolo_pd_pallas(a, *, l0: Optional[float] = None,
                   r: Optional[int] = None, max_iters: int = 6,
                   want_h: bool = False, qr_mode: str = "cholqr2",
                   qr_iters: int = 1, hermitian_source=None,
                   schedule=None, bn: int = 256, bk: int = 512,
                   bm: int = 256, use_pallas: bool = True):
    """Unrolled Zolo-PD (same contract as
    :func:`repro.core.zolo.zolo_pd_static`) with the iteration's Gram
    product and r-term combine running on the Pallas kernels.

    ``a`` must be pre-scaled (sigma_max <= 1) with singular values in
    [l0, 1]; a plan-precomputed ``schedule`` takes precedence over
    ``l0``/``r``/``max_iters``.  ``bn``/``bk``/``bm`` select kernel tile
    sizes (threaded from ``SvdConfig.extra`` by the planner).  Returns
    (Q, H or None, PolarInfo).
    """
    ops = pallas_zolo_ops(bn=bn, bk=bk, bm=bm, use_pallas=use_pallas)
    return _zolo.zolo_pd_static(
        a, l0=l0, r=r, max_iters=max_iters, want_h=want_h,
        qr_mode=qr_mode, qr_iters=qr_iters,
        hermitian_source=hermitian_source, schedule=schedule, ops=ops)


def zolo_pd_pallas_dynamic(a, r: int = 3, *, alpha=None, l=None,
                           max_iters: int = 8, eps=None,
                           want_h: bool = True, first_mode: str = "auto",
                           hh_block: int = 32, bn: int = 256,
                           bk: int = 512, bm: int = 256,
                           use_pallas: bool = True):
    """Dynamic Zolo-PD (same contract as
    :func:`repro.core.zolo.zolo_pd`) with the iteration's Gram product
    and r-term combine running on the Pallas kernels — the (dynamic
    schedule, Pallas ops) binding of the engine.

    Coefficients are computed in-graph from the running lower bound, so
    one compiled executable serves any conditioning while the hot loops
    stay on the fused kernels.  The ``lax.while_loop`` body traces the
    kernels once (no static-schedule unrolling), so the kernel count in
    the compiled module is O(1) in the iteration count.  ``bn``/``bk``/
    ``bm`` select kernel tile sizes (threaded from ``SvdConfig.extra``
    by the planner).  Returns (Q, H or None, PolarInfo).
    """
    ops = pallas_zolo_ops(bn=bn, bk=bk, bm=bm, use_pallas=use_pallas)
    return _zolo.zolo_pd(a, r, alpha=alpha, l=l, max_iters=max_iters,
                         eps=eps, want_h=want_h, first_mode=first_mode,
                         hh_block=hh_block, ops=ops)
