"""Zolo-PD: polar decomposition via composed Zolotarev functions.

Paper Algorithm 1 / Algorithm 3, adapted to TPU per DESIGN.md §3:

* The r independent terms of eq. (12) are evaluated as one *batched*
  computation over a leading ``r`` axis (maps to the paper's r process
  groups; on a TPU slice the batch either vmaps onto the MXU or is split
  over a mesh axis by ``repro.dist.grouped``).
* **Gram sharing** (beyond-paper): within one address space the Gram
  product ``G = X^T X`` is computed once and shared by all r shifted
  factorizations Z_j = G + c_{2j-1} I.  The paper-faithful grouped mode
  (each group recomputes G) lives in ``repro.dist.grouped``.
* The first (ill-conditioned) iteration uses the *structured QR* of
  ``[X; sqrt(c) I]`` — either the paper-faithful blocked Householder
  (:mod:`repro.core.structured_qr`, MPDGEQRF/MPDORGQR analogue) or the
  TPU-native shifted CholeskyQR2 — selected by ``qr_mode``.

One engine, two orthogonal choices
----------------------------------

Every Zolo-PD backend in this repo is the SAME iteration, specialized
along two independent axes:

* **schedule source** — where the per-iteration coefficients come from:
  :func:`run_schedule` (a trace-time precomputed
  :func:`repro.core.coeffs.zolo_schedule_np` list, fully unrolled) or
  :func:`run_dynamic` (in-graph coefficients from the running lower
  bound ``l`` inside a ``lax.while_loop``, with the peeled
  stability-regime first iteration).
* **:class:`ZoloOps` execution bundle** — where the compute runs: the
  default jnp/einsum ops, the fused Pallas kernels
  (:func:`repro.core.zolo_pallas.pallas_zolo_ops`), or the
  sep-/zolo-collective distributed ops
  (:mod:`repro.dist.grouped_ops`).

Both loops share :func:`zolo_iteration` — the ONE iteration body.  The
public drivers are thin bindings of a (schedule source, ops bundle)
pair:

======================  ===============  ==========================
driver                  schedule source  ops bundle
======================  ===============  ==========================
``zolo_pd``             dynamic          any (default jnp)
``zolo_pd_static``      static           any (default jnp)
``zolo_pd_pallas``      static           ``pallas_zolo_ops``
``zolo_pd_pallas_dynamic``  dynamic      ``pallas_zolo_ops``
``grouped_zolo_pd_static``  static       sep/zolo-collective
``grouped_zolo_pd_dynamic`` dynamic      sep/zolo-collective
======================  ===============  ==========================

A new backend is a new pair, never a fifth loop.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import coeffs as _coeffs
from repro.core import norms as _norms
from repro.core.qdwh import PolarInfo, form_h
from repro.core.structured_qr import structured_qr_q1q2 as _structured_qr_q1q2


# Ridge floor multiplier for the shifted-Gram coefficient in sub-f64
# iterates: c is clamped to >= factor * eps(accum dtype) * max diag(G)
# before Z = G + cI is factorized.  At kappa >~ 1e4 the odd Zolotarev
# shifts fall below the Gram's eps-level negative eigenvalue noise and
# the Cholesky goes indefinite (NaN); an eps-of-the-accumulator ridge is
# below G's own rounding error so clean solves are unperturbed.  Keep in
# sync with ``repro.kernels.gram.SHIFT_RIDGE_FACTOR`` (the in-kernel
# clamp on the fused shifted-Gram path).
SHIFT_RIDGE_FACTOR = 8.0


def _clamp_shift(c_odd, g, dtype):
    """Shift clamp: ridge positive Gram shifts for itemsize <= 4 iterates.

    f64 numerics are untouched — the f64 dynamic driver runs shifts of
    ~1e-20 at kappa 1e10 today, far below any eps-level floor, and
    clamping them would change converged results."""
    if jnp.dtype(dtype).itemsize > 4:
        return c_odd
    accum = jnp.promote_types(dtype, jnp.float32)
    diag_max = jnp.max(jnp.diagonal(g, axis1=-2, axis2=-1))
    floor = (SHIFT_RIDGE_FACTOR * jnp.finfo(accum).eps
             * jnp.maximum(diag_max, 0.0)).astype(c_odd.dtype)
    return jnp.where(c_odd > 0, jnp.maximum(c_odd, floor), c_odd)


def _gram(x, c=0.0):
    """G = X^T X (+ c I) with f32-or-better accumulation."""
    g = jnp.einsum("...mk,...mn->...kn", x, x,
                   preferred_element_type=jnp.promote_types(x.dtype,
                                                            jnp.float32))
    if isinstance(c, (int, float)) and c == 0.0:
        return g
    n = x.shape[-1]
    # the f32-accumulated shifted Gram gets the same shift clamp as the
    # Pallas kernel (f64 accumulation passes through _clamp_shift intact)
    c_arr = _clamp_shift(jnp.asarray(c, g.dtype), g, g.dtype)
    return g + c_arr * jnp.eye(n, dtype=g.dtype)


def _polar_update(x, t, a, mhat):
    """X2 = mhat * (X + sum_j a_j T_j) over stacked terms t: (r, ..., m, n).

    The combine runs at the term dtype (f32-or-better: a sub-f32 iterate's
    terms come out of f32-accumulated factorizations) and the result is
    cast back to the iterate dtype, so a bf16 iterate stays bf16."""
    s = jnp.einsum("j,j...mn->...mn", a.astype(t.dtype), t)
    return (mhat * (x + s)).astype(x.dtype)


def _coeff_select_all(c_odd, a):
    """Default coefficient selector: this executor evaluates all r terms."""
    return c_odd, a


class ZoloOps(NamedTuple):
    """Injectable compute ops for the Zolotarev iteration hot spots.

    The engine below routes its hot loops through this bundle, so a
    backend can swap the default jnp/einsum path for fused kernels
    (``repro.core.zolo_pallas`` builds one on the Pallas kernels in
    :mod:`repro.kernels`) or for collective distributed versions
    (``repro.dist.grouped_ops`` all-reduces partial Grams over the
    intra-group "sep" mesh axis and fuses the r-term combine into the
    "zolo" psum) without touching the driver logic.

    * ``gram(x, c=0.0)``          -> X^T X + c I, f32-or-better
      accumulation (callers cast the result to the working dtype).
      ``x`` is the iterate (or a factor sharing its row distribution,
      e.g. the CholeskyQR2 Q1): a distributed implementation holds an
      (m/sep, n) row block and must all-reduce the partial product to
      the *global* Gram.
    * ``gram_local(q, c=0.0)``    -> same contract for an operand that
      is *replicated* (not row-distributed) — the CholeskyQR2 identity
      block Q2.  Never cross-device-reduced; single-address-space
      bundles point it at the same implementation as ``gram``.
    * ``polar_update(x, t, a, mhat)`` -> mhat * (X + sum_j a[j] T[j])
      with ``t`` the stacked (r, m, n) terms — the iteration combine
      (paper's DGSUM2D role).  A grouped bundle contributes
      ``mhat * (xw * X + a * T)`` with ``xw`` one-hot over groups and
      psums over "zolo" so the collective output IS the next iterate.
    * ``coeff_select(c_odd, a)``  -> the (c_odd, a) slice THIS executor
      evaluates.  The dynamic engine computes all r in-graph
      coefficients on every device and selects through this hook; the
      default keeps all r (single-address-space batched terms), a
      grouped bundle takes its own group's length-1 slice via
      ``axis_index("zolo")``.  (Static schedules select by data layout
      instead — the shard_map in_specs split the coefficient arrays —
      so :func:`run_schedule` never calls this.)
    * ``fnorm(x)``                -> global Frobenius norm of the
      (possibly row-distributed) iterate, for the dynamic engine's
      residual stopping rule; a sep-distributed bundle psums the local
      sum of squares.
    * ``fnorm_pair(a, b)``        -> length-2 vector of both Frobenius
      norms at once — the dynamic engine's residual test needs
      ``||X1 - X0||`` and ``||X1||`` together, and a sep-distributed
      bundle fuses both sums-of-squares into ONE all-reduce (two
      ``fnorm`` calls would pay two collectives per iteration on the
      convergence-check critical path).
    """

    gram: Callable = _gram
    polar_update: Callable = _polar_update
    gram_local: Callable = _gram
    coeff_select: Callable = _coeff_select_all
    fnorm: Callable = _norms.frobenius
    fnorm_pair: Callable = _norms.frobenius_pair


DEFAULT_OPS = ZoloOps()


def _chol_terms(x, c_odd, gram=None, *, ops: ZoloOps = DEFAULT_OPS):
    """T_j = X (X^T X + c_{2j-1} I)^{-1} for all j, batched over r.

    Returns W with shape (r, ..., n, m) holding Z_j^{-1} X^T (transposed
    terms); callers combine as sum_j a_j W_j^T.
    """
    n = x.shape[-1]
    # factorizations run at f32-or-better whatever the iterate dtype:
    # lax.linalg has no sub-f32 kernels, and a bf16 iterate's terms come
    # out of the f32-accumulated Gram anyway
    fdtype = jnp.promote_types(x.dtype, jnp.float32)
    r = c_odd.shape[0]
    if gram is None and r == 1:
        # single-term executor (the grouped r-sharded case): fold the
        # shift into the Gram call itself so a collective bundle carries
        # it inside the "sep" psum (fused shifted Gram) and the
        # kernel-/gram-side shift clamp applies
        z = ops.gram(x, c_odd.astype(fdtype)[0])[None].astype(fdtype)
    else:
        g = (ops.gram(x) if gram is None else gram).astype(fdtype)
        eye = jnp.eye(n, dtype=fdtype)
        c_eff = _clamp_shift(c_odd.astype(fdtype), g, x.dtype)
        z = g[None] + c_eff[:, None, None] * eye  # (r, n, n)
    l = jnp.linalg.cholesky(z)
    xt = jnp.broadcast_to(
        jnp.swapaxes(x, -1, -2).astype(fdtype),
        (r,) + x.shape[:-2] + (n, x.shape[-2]))
    y = jax.lax.linalg.triangular_solve(l, xt, left_side=True, lower=True)
    w = jax.lax.linalg.triangular_solve(
        l, y, left_side=True, lower=True, transpose_a=True)
    return w  # (r, n, m), fdtype


def term_sum_chol(x, c_odd, a, gram=None, *, ops: ZoloOps = DEFAULT_OPS):
    """sum_j a_j X (X^T X + c_{2j-1} I)^{-1} over the given (possibly
    partial) odd-coefficient slice — the Cholesky-variant Zolotarev term.

    Kept for callers wanting the bare term; the drivers go through
    :func:`zolo_iteration`."""
    w = _chol_terms(x, c_odd, gram=gram, ops=ops)
    return jnp.einsum("j,jnm->mn", a.astype(w.dtype), w).astype(x.dtype)


def term_sum_cholqr2(x, c_odd, a, *, ops: ZoloOps = DEFAULT_OPS):
    """sum_j (a_j / sqrt(c_j)) Q1_j Q2_j^T via shifted CholeskyQR2
    (eq. 12 analogue) over the given odd-coefficient slice.

    Q1_j = X R_j^{-1}, Q2_j = sqrt(c_j) R_j^{-1} with R_j from a two-pass
    shifted Cholesky QR of [X; sqrt(c_j) I].  Explicit Q (paper's MPDORGQR
    role) keeps the term stable for much smaller c_j than a single
    Cholesky.

    Both Gram passes route through ``ops``: the first (and the Q1 part
    of the second) uses ``ops.gram`` — Q1 shares X's row distribution —
    while the replicated identity-block part Q2^T Q2 uses
    ``ops.gram_local`` so a sep-distributed bundle does not all-reduce
    (and thereby over-count) it."""
    n = x.shape[-1]
    # factorizations at f32-or-better (see _chol_terms); the clamp below
    # ridges only Z's shift — sqrt_c and the final weights keep the exact
    # c so pass 2 still corrects to the true QR of [X; sqrt(c) I]
    fdtype = jnp.promote_types(x.dtype, jnp.float32)
    r = c_odd.shape[0]
    c_odd_f = c_odd.astype(fdtype)
    sqrt_c = jnp.sqrt(c_odd_f)
    eye = jnp.eye(n, dtype=fdtype)

    if r == 1:
        # fused shifted Gram: the shift rides the collective (see
        # _chol_terms); the gram implementation applies the shift clamp
        z = ops.gram(x, c_odd_f[0])[None].astype(fdtype)
    else:
        g = ops.gram(x).astype(fdtype)
        c_eff = _clamp_shift(c_odd_f, g, x.dtype)
        z = g[None] + c_eff[:, None, None] * eye
    l1 = jnp.linalg.cholesky(z)  # R1 = L1^T
    xb = jnp.broadcast_to(x.astype(fdtype), (r,) + x.shape)
    # Q1 = X R1^{-1}  (right-solve against upper-triangular R1 = L1^T)
    q1 = jax.lax.linalg.triangular_solve(
        l1, xb, left_side=False, lower=True, transpose_a=True)
    # Q2 = sqrt(c) R1^{-1}
    q2 = sqrt_c[:, None, None] * jax.lax.linalg.triangular_solve(
        l1, jnp.broadcast_to(eye, (r, n, n)),
        left_side=False, lower=True, transpose_a=True)
    # Second pass restores orthogonality: G2 = Q^T Q = Q1^T Q1 + Q2^T Q2.
    # The Grams take the *iterate* dtype so a sub-f32 bundle's kernels
    # run the production precision (no-op cast for f32/f64).
    g2 = (ops.gram(q1.astype(x.dtype))
          + ops.gram_local(q2.astype(x.dtype))).astype(fdtype)
    l2 = jnp.linalg.cholesky(g2)
    q1 = jax.lax.linalg.triangular_solve(
        l2, q1, left_side=False, lower=True, transpose_a=True)
    q2 = jax.lax.linalg.triangular_solve(
        l2, q2, left_side=False, lower=True, transpose_a=True)
    return jnp.einsum("j,jmk,jnk->mn", a.astype(fdtype) / sqrt_c, q1, q2)


def term_sum_householder(x, c_odd, a, block: int = 32, *,
                         ops: ZoloOps = DEFAULT_OPS):
    """sum_j (a_j / sqrt(c_j)) Q1_j Q2_j^T via blocked *structured*
    Householder QR of [X; sqrt(c_j) I] (MPDGEQRF/MPDORGQR analogue, §3.1)
    over the given odd-coefficient slice.

    ``ops`` is accepted for term-signature uniformity only: the blocked
    Householder QR has no kernel or sep-distributed implementation, so
    this term requires the *full* (undistributed) ``x`` — the grouped
    drivers reject it on a sep>1 mesh."""
    dtype = jnp.promote_types(x.dtype, jnp.float32)
    x = x.astype(dtype)  # the blocked QR has no sub-f32 path
    terms = []
    for j in range(c_odd.shape[0]):
        q1, q2 = _structured_qr_q1q2(x, jnp.sqrt(c_odd[j]).astype(dtype),
                                     block=block)
        terms.append((a[j] / jnp.sqrt(c_odd[j])).astype(dtype)
                     * jnp.einsum("mk,nk->mn", q1, q2))
    return sum(terms)


ITER_MODES = ("chol", "cholqr2", "householder")


def _validate_iter_mode(name: str, value: str, extra=()) -> None:
    """ValueError (not a downstream failure) for an unknown iteration
    mode, listing the valid choices."""
    valid = sorted(ITER_MODES) + list(extra)
    if value not in valid:
        raise ValueError(f"unknown {name}: {value!r} (one of {valid})")


def zolo_iteration(x, c_odd, a, mhat, *, mode: str = "chol",
                   ops: ZoloOps = DEFAULT_OPS, hh_block: int = 32):
    """THE Zolotarev iteration body (Alg. 1 step 4 / Alg. 3 step 4).

    X -> mhat * (X + sum_j a_j T_j(c_{2j-1})) with the shifted
    factorization for T_j picked by ``mode``:

    * ``"chol"``        — shared-Gram Cholesky (eq. 4 analogue; the
      steady-state term once the interval has left the stiff regime).
    * ``"cholqr2"``     — shifted CholeskyQR2 (TPU-native stable
      first-iteration term).
    * ``"householder"`` — blocked structured Householder QR (paper
      §3.1; paper-faithful stable term, not row-distributable).

    ``c_odd``/``a`` hold the odd shifts c_{2j-1} and weights a_j of the
    terms THIS executor evaluates — all r in the single-address-space
    drivers, this group's length-1 slice under ``repro.dist.grouped``.
    Every schedule source (static or dynamic) and every ops bundle
    (jnp, Pallas, sep-collective) runs through this one body: there is
    no forked per-driver iteration math anywhere else.
    """
    if mode == "chol":
        w = _chol_terms(x, c_odd, ops=ops)    # (r, ..., n, m)
        t = jnp.swapaxes(w, -1, -2)           # stacked terms (r, ..., m, n)
        return ops.polar_update(x, t, a, mhat)
    if mode == "cholqr2":
        # the QR-form terms fold the a_j weights into their sum, so the
        # combine sees one pre-summed term with unit weight
        t = term_sum_cholqr2(x, c_odd, a, ops=ops)
    elif mode == "householder":
        t = term_sum_householder(x, c_odd, a, block=hh_block, ops=ops)
    else:
        _validate_iter_mode("mode", mode)
    one = jnp.ones((1,), jnp.promote_types(x.dtype, jnp.float32))
    return ops.polar_update(x, t[None], one, mhat)


def run_schedule(x, c_odd, a_wts, mhats, *, qr_mode: str = "cholqr2",
                 qr_iters: int = 1, ops: ZoloOps = DEFAULT_OPS,
                 hh_block: int = 32):
    """THE static schedule source: the trace-time coefficient schedule,
    fully unrolled over :func:`zolo_iteration`.

    ``c_odd`` (iters, r_local) / ``a_wts`` (iters, r_local) /
    ``mhats`` (iters,) are the stacked per-iteration coefficients —
    r_local = r for the batched single-address-space drivers, 1 for a
    grouped shard_map body whose in_specs split the arrays over "zolo".
    The first ``qr_iters`` iterations use the stable-regime ``qr_mode``
    term; the rest use the shared-Gram Cholesky term.
    """
    for i in range(c_odd.shape[0]):
        mode = qr_mode if i < qr_iters else "chol"
        x = zolo_iteration(x, c_odd[i], a_wts[i], mhats[i], mode=mode,
                           ops=ops, hh_block=hh_block)
    return x


def run_dynamic(x0, l0, r: int, *, eps: float, max_iters: int = 8,
                first_mode: str = "auto", hh_block: int = 32,
                ops: ZoloOps = DEFAULT_OPS, allow_householder: bool = True):
    """THE dynamic schedule source: in-graph Zolotarev coefficients from
    the running lower bound, so one compiled executable serves any
    conditioning.

    The *first* iteration is peeled out of the while-loop and selects
    its factorization by stability regime (the paper's QR-first policy):

      l <  ~10 sqrt(eps)  -> structured Householder QR  (paper §3.1)
      l <  0.05           -> shifted CholeskyQR2         (TPU fast path)
      else                -> shared-Gram Cholesky        (eq. 4 analogue)

    ``first_mode`` in {"auto", "householder", "cholqr2", "chol"} —
    "auto" switches at runtime via lax.switch; a static choice compiles
    only one branch.  ``allow_householder=False`` substitutes the
    shifted CholeskyQR2 term in the extreme regime (a row-distributed
    ops bundle cannot run the structured Householder QR).  All remaining
    iterations use the shared-Gram Cholesky form (after one Zolotarev
    map the interval is always in Cholesky range).

    The stopping rule is the paper's residual criterion (Alg. 1 step 4e)
    only: an interval-bound certificate (stop when l >= 1 - O(eps)) is
    unsound in finite precision at extreme kappa — the fp iterate lags
    the exact-arithmetic l recursion (measured: orth 4e-5 where the
    certificate claimed convergence at kappa 1e16).  The residual rule
    reproduces the paper's *measured* Tables 5/10 (theory + <= 1).

    Every coefficient set passes through ``ops.coeff_select`` (a grouped
    bundle takes its group's slice) and residual norms through
    ``ops.fnorm`` (a distributed bundle all-reduces), so the SAME loop
    runs single-device, kernel-backed, and grouped.  Returns
    ``(x, l_final, iterations, residual, converged)``: ``converged`` is
    carried through the loop state and records whether the residual
    rule was met — an exit at ``max_iters`` with the rule unmet used to
    be indistinguishable from convergence, which is exactly the silent
    failure the resilience layer's verdicts key on.
    """
    dtype = x0.dtype
    # floor the residual tolerance at a few iterate-dtype eps: a bf16
    # iterate's step-to-step residual bottoms out near eps(bf16), below
    # which the f32-accumulation tol (e.g. r=1) would never be met
    tol = max(eps ** (1.0 / (2 * r + 1)),
              4.0 * float(jnp.finfo(dtype).eps))
    hh_thresh = 10.0 * eps ** 0.5
    qr_thresh = 0.05

    # --- peeled first iteration -------------------------------------------
    c0, a0, m0 = _coeffs.zolo_coeffs(l0, r)
    c0_odd = c0[0::2]

    def first(x_, mode):
        c_sel, a_sel = ops.coeff_select(c0_odd, a0)
        return zolo_iteration(x_, c_sel, a_sel, m0, mode=mode, ops=ops,
                              hh_block=hh_block)

    hh_mode = "householder" if allow_householder else "cholqr2"
    if first_mode == "auto":
        branch = (jnp.int32(0) + (l0 >= hh_thresh).astype(jnp.int32)
                  + (l0 >= qr_thresh).astype(jnp.int32))
        x1 = jax.lax.switch(
            branch,
            [lambda x_: first(x_, hh_mode),
             lambda x_: first(x_, "cholqr2"),
             lambda x_: first(x_, "chol")],
            x0)
    else:
        x1 = first(x0, first_mode)
    nrm1 = ops.fnorm_pair(x1 - x0, x1)  # one fused reduction for both
    res1 = nrm1[0] / jnp.maximum(nrm1[1], jnp.finfo(dtype).tiny)
    l1 = jnp.clip(_coeffs.zolo_l_update(l0, c0, m0), 0.0, 1.0 - eps)

    # --- remaining iterations: shared-Gram Cholesky ------------------------
    def cond(state):
        _, _, k, res, _ = state
        return jnp.logical_and(k < max_iters, res > tol)

    def body(state):
        x, l, k, _, _ = state
        c, av, mh = _coeffs.zolo_coeffs(l, r)
        c_sel, a_sel = ops.coeff_select(c[0::2], av)
        x_new = zolo_iteration(x, c_sel, a_sel, mh, mode="chol", ops=ops)
        nrm = ops.fnorm_pair(x_new - x, x_new)
        res = nrm[0] / jnp.maximum(nrm[1], jnp.finfo(dtype).tiny)
        l_new = jnp.clip(_coeffs.zolo_l_update(l, c, mh), 0.0, 1.0 - eps)
        return x_new, l_new, k + 1, res, res <= tol

    return jax.lax.while_loop(cond, body,
                              (x1, l1, jnp.int32(1), res1, res1 <= tol))


def zolo_pd_static(a, *, l0: Optional[float] = None,
                   r: Optional[int] = None, max_iters: int = 6,
                   want_h: bool = False, qr_mode: str = "cholqr2",
                   qr_iters: int = 1, hermitian_source=None,
                   schedule=None, ops: Optional[ZoloOps] = None):
    """Unrolled Zolo-PD with a trace-time coefficient schedule — the
    (static schedule, ``ops``) binding of the engine.

    ``a`` must be pre-scaled (sigma_max <= 1) with singular values in
    [l0, 1].  The first ``qr_iters`` iterations use ``qr_mode``
    ("cholqr2" | "householder" | "chol"); the rest use the shared-Gram
    Cholesky variant.  A precomputed ``schedule`` (sequence of
    :class:`repro.core.coeffs.ZoloIteration`, e.g. bound once by an
    ``SvdPlan``) takes precedence over ``l0``/``r``/``max_iters``.
    ``ops`` swaps the iteration's compute ops for an alternative
    :class:`ZoloOps` bundle — the hook the kernel-backed ``zolo_pallas``
    backend plugs into.  Returns (Q, H or None, PolarInfo).
    """
    _validate_iter_mode("qr_mode", qr_mode)
    ops = DEFAULT_OPS if ops is None else ops
    if schedule is not None:
        sched = list(schedule)
    elif l0 is not None:
        if r is None:
            r = _coeffs.choose_r(1.0 / float(l0))
        sched = _coeffs.zolo_schedule_np(float(l0), r, max_iters=max_iters)
    else:
        raise ValueError("zolo_pd_static needs l0= or a precomputed "
                         "schedule=")
    coeff_dtype = jnp.promote_types(a.dtype, jnp.float32)
    c_odd = jnp.asarray([it.c[0::2] for it in sched], coeff_dtype)
    a_wts = jnp.asarray([it.a for it in sched], coeff_dtype)
    mhats = jnp.asarray([it.mhat for it in sched], coeff_dtype)
    x = run_schedule(a, c_odd, a_wts, mhats, qr_mode=qr_mode,
                     qr_iters=qr_iters, ops=ops)
    src = a if hermitian_source is None else hermitian_source
    info = PolarInfo(iterations=jnp.int32(len(sched)),
                     residual=jnp.asarray(0.0, a.dtype),
                     l_final=jnp.asarray(sched[-1].l_after, jnp.float32),
                     converged=jnp.asarray(True),
                     l_init=jnp.asarray(sched[0].l_before, jnp.float32))
    if want_h:
        return x, form_h(x, src), info
    return x, None, info


def zolo_pd(a, r: int = 3, *, alpha=None, l=None, max_iters: int = 8,
            eps: Optional[float] = None, want_h: bool = True,
            first_mode: str = "auto", hh_block: int = 32,
            ops: Optional[ZoloOps] = None):
    """Dynamic Zolo-PD (paper Alg. 1/3) of ``a`` with m >= n — the
    (dynamic schedule, ``ops``) binding of the engine.

    ``r`` is static (it fixes array shapes); coefficients are computed
    in-graph from the running lower bound via the JAX elliptic functions,
    so a single compiled function serves any conditioning (see
    :func:`run_dynamic` for the first-iteration regime switch and the
    residual stopping rule).  ``ops`` swaps the iteration's compute ops
    for an alternative :class:`ZoloOps` bundle — the hook the
    kernel-backed ``zolo_pallas_dynamic`` backend plugs into.
    """
    _validate_iter_mode("first_mode", first_mode, extra=("auto",))
    ops = DEFAULT_OPS if ops is None else ops
    dtype = a.dtype
    # stopping tolerance from the *accumulation* precision: a bf16
    # iterate's factorizations and Grams accumulate in f32, and
    # eps(bf16) ~ 8e-3 as a base tolerance would stop after one step
    eps = eps or float(jnp.finfo(jnp.promote_types(dtype,
                                                   jnp.float32)).eps)
    # alpha must be a guaranteed upper bound (paper: alpha assumed known/
    # estimated); the loose bound costs a few extra decades of l, which at
    # Zolotarev convergence rates is at most one extra iteration.  Callers
    # with sharp knowledge (paper Table 3 setting) pass alpha explicitly.
    alpha = _norms.sigma_max_upper(a) if alpha is None else jnp.asarray(alpha)
    x0 = a / alpha.astype(dtype)
    l0 = _norms.sigma_min_lower_qr(x0) if l is None else jnp.asarray(l)
    l0 = jnp.clip(l0, 4 * eps, 1.0 - eps)
    l0 = l0.astype(jnp.result_type(l0, 0.0))
    x, l_fin, k, res, conv = run_dynamic(x0, l0, r, eps=eps,
                                         max_iters=max_iters,
                                         first_mode=first_mode,
                                         hh_block=hh_block, ops=ops)
    info = PolarInfo(iterations=k, residual=res, l_final=l_fin,
                     converged=conv, l_init=l0.astype(jnp.float32))
    if want_h:
        return x, form_h(x, a), info
    return x, None, info


def polar_canonical(a):
    """Return (a_work, transposed) with a_work.shape[-2] >= a_work.shape[-1].

    polar(A^T) = polar(A)^T for the orthogonal factor; callers transpose
    back.  Keeps the Gram matrix at min(m, n)^2.
    """
    m, n = a.shape[-2:]
    if m >= n:
        return a, False
    return jnp.swapaxes(a, -1, -2), True
