"""Scaled Newton polar iteration (paper §2 intro; Higham 2008).

X_{k+1} = (zeta_k X_k + X_k^{-T} / zeta_k) / 2, for square nonsingular A.
Included as the classical baseline the PD literature (and the paper's
intro) compares against.  Uses 1,inf-norm scaling; inversion via LU solve.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import norms as _norms
from repro.core.qdwh import PolarInfo, form_h


def scaled_newton_pd(a, *, max_iters: int = 30, eps=None, want_h: bool = True):
    if a.shape[-2] != a.shape[-1]:
        raise ValueError("scaled Newton requires a square matrix")
    dtype = a.dtype
    eps = eps or float(jnp.finfo(dtype).eps)
    tol = 10 * eps

    def norm1(x):
        return jnp.max(jnp.sum(jnp.abs(x), axis=-2))

    def norminf(x):
        return jnp.max(jnp.sum(jnp.abs(x), axis=-1))

    def cond(state):
        x, _, k, res = state
        return jnp.logical_and(k < max_iters, res > tol)

    def body(state):
        x, _, k, _ = state
        xinv_t = jnp.linalg.inv(x).swapaxes(-1, -2)
        # (1, inf)-norm scaling (Higham): zeta = (|X^-1|_1 |X^-1|_inf
        #                                        / (|X|_1 |X|_inf))^(1/4)
        zeta = ((norm1(xinv_t) * norminf(xinv_t))
                / (norm1(x) * norminf(x))) ** 0.25
        zeta = zeta.astype(dtype)
        x_new = 0.5 * (zeta * x + xinv_t / zeta)
        res = _norms.frobenius(x_new - x) / _norms.frobenius(x_new)
        return x_new, x, k + 1, res

    init = (a / _norms.frobenius(a).astype(dtype) * jnp.asarray(1.0, dtype),
            jnp.zeros_like(a), jnp.int32(0), jnp.asarray(1.0, dtype))
    x, _, k, res = jax.lax.while_loop(cond, body, init)
    info = PolarInfo(iterations=k, residual=res,
                     l_final=jnp.asarray(1.0, jnp.float32),
                     converged=res <= tol,
                     l_init=jnp.asarray(float("nan"), jnp.float32))
    if want_h:
        return x, form_h(x, a), info
    return x, None, info
