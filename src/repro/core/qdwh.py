"""QDWH-PD: QR-based dynamically weighted Halley polar decomposition.

Paper §2.1 (eqs. 2-4).  The baseline the paper compares Zolo-PD against.

Two drivers:

* :func:`qdwh_pd`        — dynamic: coefficients from a runtime lower bound
                           ``l`` inside a ``lax.while_loop``; per-iteration
                           QR (eq. 3) vs Cholesky (eq. 4) switch at
                           ``c_k <= 100`` exactly as suggested in [31]/§2.1.
* :func:`qdwh_pd_static` — trace-time schedule (unrolled); used inside
                           compiled train steps and dry-runs.

Both return ``(Q, H, info)`` with ``A = Q H``; set ``want_h=False`` to skip
forming H (the Muon path only needs Q).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import coeffs as _coeffs
from repro.core import norms as _norms


class PolarInfo(NamedTuple):
    """Convergence record; a NamedTuple so compiled (jit) plans return it.

    ``converged`` is the runtime verdict the resilience layer keys on: a
    dynamic driver's ``while_loop`` can exit at the iteration cap with
    the residual rule unmet, and before this flag existed that exit was
    silent (the factors just carried reduced accuracy — or NaN — out).
    Static trace-time schedules are converged by construction (their
    depth was sized from l0 at plan time).  ``l_init`` records the
    sigma_min lower bound the solve actually ran under — the runtime
    analogue of the plan's kappa hint (kappa_est = 1/l_init), NaN when
    the driver has no bound (Newton, the SVD oracle, a schedule-only
    static call).
    """

    iterations: jnp.ndarray  # scalar int32
    residual: jnp.ndarray  # final ||X2 - X1||_F / ||X2||_F
    l_final: jnp.ndarray
    # Python-scalar defaults (not jnp arrays: no device work at class
    # definition) keep three-field construction by out-of-tree backends
    # valid; every in-repo driver sets both explicitly.
    converged: jnp.ndarray = True  # scalar bool: stopping rule met
    l_init: jnp.ndarray = float("nan")  # f32 entry bound; NaN unknown


def _eps_for(dtype) -> float:
    return float(jnp.finfo(dtype).eps)


def form_h(q, a):
    """H = (Q^T A + (Q^T A)^T) / 2 — the Hermitian polar factor."""
    qa = jnp.einsum("...mk,...mn->...kn", q, a)
    return 0.5 * (qa + jnp.swapaxes(qa, -1, -2))


def _qdwh_qr_iter(x, a, b, c):
    """Inverse-free QR iteration (eq. 3): X+ = (b/c) X + (a - b/c)/sqrt(c) Q1 Q2^T."""
    m, n = x.shape[-2:]
    dtype = x.dtype
    stacked = jnp.concatenate(
        [jnp.sqrt(c).astype(dtype) * x,
         jnp.broadcast_to(jnp.eye(n, dtype=dtype), x.shape[:-2] + (n, n))],
        axis=-2)
    q, _ = jnp.linalg.qr(stacked)
    q1 = q[..., :m, :]
    q2 = q[..., m:, :]
    coef = ((a - b / c) / jnp.sqrt(c)).astype(dtype)
    return (b / c).astype(dtype) * x + coef * jnp.einsum(
        "...mk,...nk->...mn", q1, q2)


def _qdwh_chol_iter(x, a, b, c):
    """Cholesky iteration (eq. 4): Z = I + c X^T X, X+ = (b/c)X + (a-b/c) X Z^{-1}."""
    n = x.shape[-1]
    dtype = x.dtype
    g = jnp.einsum("...mk,...mn->...kn", x, x,
                   preferred_element_type=jnp.promote_types(
                       dtype, jnp.float32)).astype(dtype)
    z = c.astype(dtype) * g + jnp.eye(n, dtype=dtype)
    l = jnp.linalg.cholesky(z)
    # W = Z^{-1} X^T via two triangular solves.
    xt = jnp.swapaxes(x, -1, -2)
    y = jax.lax.linalg.triangular_solve(l, xt, left_side=True, lower=True)
    w = jax.lax.linalg.triangular_solve(
        l, y, left_side=True, lower=True, transpose_a=True)
    xz = jnp.swapaxes(w, -1, -2)
    return (b / c).astype(dtype) * x + (a - b / c).astype(dtype) * xz


def qdwh_pd(a, *, alpha=None, l=None, max_iters: int = 12,
            eps: Optional[float] = None, want_h: bool = True,
            chol_switch: float = 100.0):
    """Dynamic QDWH polar decomposition of ``a`` (m >= n)."""
    dtype = a.dtype
    eps = eps or _eps_for(dtype)
    alpha = _norms.sigma_max_upper(a) if alpha is None else jnp.asarray(alpha)
    x0 = a / alpha.astype(dtype)
    l0 = _norms.sigma_min_lower_qr(x0) if l is None else jnp.asarray(l)
    l0 = jnp.clip(l0, 4 * eps, 1.0 - eps)
    tol = eps ** (1.0 / 3.0)

    def cond(state):
        x, _, l, k, res, _ = state
        return jnp.logical_and(k < max_iters, res > tol)

    def body(state):
        x, _, l, k, _, _ = state
        ca, cb, cc = _coeffs.qdwh_coeffs(l)
        x_new = jax.lax.cond(
            cc > chol_switch,
            lambda x_: _qdwh_qr_iter(x_, ca, cb, cc),
            lambda x_: _qdwh_chol_iter(x_, ca, cb, cc),
            x)
        res = _norms.frobenius(x_new - x) / jnp.maximum(
            _norms.frobenius(x_new), jnp.finfo(dtype).tiny)
        l_new = jnp.clip(_coeffs.qdwh_l_update(l, ca, cb, cc), 0.0, 1.0)
        return x_new, x, l_new, k + 1, res, res <= tol

    init = (x0, jnp.zeros_like(x0), l0.astype(jnp.result_type(l0, 0.0)),
            jnp.int32(0), jnp.asarray(1.0, dtype), jnp.asarray(False))
    x, _, l_fin, k, res, conv = jax.lax.while_loop(cond, body, init)
    info = PolarInfo(iterations=k, residual=res, l_final=l_fin,
                     converged=conv, l_init=l0.astype(jnp.float32))
    if want_h:
        return x, form_h(x, a), info
    return x, None, info


def qdwh_pd_static(a, *, l0: Optional[float] = None, max_iters: int = 8,
                   want_h: bool = True, qr_iters: Optional[int] = None,
                   schedule=None):
    """Unrolled QDWH with a trace-time coefficient schedule from ``l0``.

    ``a`` must already be scaled so that sigma_max(a) <= 1 (callers divide
    by a sigma_max upper bound first).  ``qr_iters``: how many leading
    iterations use the inverse-free QR form; default: while the schedule's
    ``c_k`` exceeds 100 (paper's switch).  A precomputed ``schedule``
    (sequence of ``(a, b, c, l)`` rows from
    :func:`repro.core.coeffs.qdwh_schedule_np`, e.g. bound by an
    ``SvdPlan``) takes precedence over ``l0``/``max_iters``.
    """
    if schedule is not None:
        sched = list(schedule)
    elif l0 is not None:
        sched = _coeffs.qdwh_schedule_np(float(l0), max_iters=max_iters)
    else:
        raise ValueError("qdwh_pd_static needs l0= or a precomputed "
                         "schedule=")
    x = a
    coeff_dtype = jnp.promote_types(a.dtype, jnp.float32)
    for i, (ca, cb, cc, _) in enumerate(sched):
        use_qr = cc > 100.0 if qr_iters is None else i < qr_iters
        fa = jnp.asarray(ca, coeff_dtype)
        fb = jnp.asarray(cb, coeff_dtype)
        fc = jnp.asarray(cc, coeff_dtype)
        if use_qr:
            x = _qdwh_qr_iter(x, fa, fb, fc)
        else:
            x = _qdwh_chol_iter(x, fa, fb, fc)
    info = PolarInfo(iterations=jnp.int32(len(sched)),
                     residual=jnp.asarray(0.0, a.dtype),
                     l_final=jnp.asarray(sched[-1][3], jnp.float32),
                     converged=jnp.asarray(True),
                     l_init=jnp.asarray(float(l0) if l0 is not None
                                        else float("nan"), jnp.float32))
    if want_h:
        return x, form_h(x, a), info
    return x, None, info
