"""Core numerics: the paper's contribution (Zolo-PD / Zolo-SVD family).

``polar_decompose`` / ``polar_svd`` here are thin back-compat wrappers
over the plan/execute surface in :mod:`repro.solver` (``SvdConfig`` ->
``plan`` -> ``SvdPlan``); hold a plan for repeated solves — it compiles
once per (shape, dtype, config) and never retraces.
"""

from repro.core.coeffs import (
    choose_r,
    qdwh_coeffs,
    qdwh_iter_count,
    qdwh_schedule_np,
    zolo_coeffs,
    zolo_coeffs_np,
    zolo_iter_count,
    zolo_schedule_np,
)
from repro.core.eig import block_jacobi_eigh, eigh, padded_block_jacobi_eigh
from repro.core.newton import scaled_newton_pd
from repro.core.norms import (
    condition_estimate,
    sigma_max_power,
    sigma_max_upper,
    sigma_min_lower,
    sigma_min_lower_qr,
)
from repro.core.qdwh import PolarInfo, form_h, qdwh_pd, qdwh_pd_static
from repro.core.registry import (
    EigSpec,
    PolarSpec,
    get_eig,
    get_polar,
    list_eig,
    list_polar,
    register_eig,
    register_polar,
)
from repro.core.structured_qr import (
    dense_stacked_qr_q1q2,
    structured_qr_factor,
    structured_qr_flops,
    structured_qr_q1q2,
)
from repro.core.svd import (
    jacobi_svd,
    orthogonality,
    polar_decompose,
    polar_svd,
    svd_residual,
)
from repro.core.zolo import (
    DEFAULT_OPS,
    ZoloOps,
    polar_canonical,
    run_dynamic,
    run_schedule,
    zolo_iteration,
    zolo_pd,
    zolo_pd_static,
)
from repro.core.zolo_pallas import (
    pallas_zolo_ops,
    zolo_pd_pallas,
    zolo_pd_pallas_dynamic,
)

__all__ = [k for k in dir() if not k.startswith("_")]
