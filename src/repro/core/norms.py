"""Spectral-bound estimators for the polar-decomposition drivers.

The paper assumes alpha >= sigma_max(A) and beta <= sigma_min(X0) are known
or cheaply estimated (§3.2, Table 3).  On TPU we estimate in-graph:

* ``sigma_max_upper``    — guaranteed upper bound  sqrt(||A||_1 ||A||_inf)
                           (capped by ||A||_F, also an upper bound).
* ``sigma_max_power``    — power iteration (sharp, lower-biased).
* ``sigma_min_lower``    — inverse power iteration on the (ridged) Gram
                           matrix; returns a deliberately deflated estimate
                           (x0.5) so the Zolotarev interval stays valid.
* ``sigma_min_lower_qr`` — one QR + inverse iteration on R; never squares
                           the condition number, so it resolves sigma_min
                           down to ~eps * sigma_max (what
                           ``condition_estimate`` uses).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def frobenius(a):
    return jnp.sqrt(jnp.sum(jnp.abs(a) ** 2))


def frobenius_pair(a, b):
    """(||a||_F, ||b||_F) as one stacked length-2 vector.

    The single-process default behind the ``ZoloOps.fnorm_pair`` slot.
    Distributed bundles override it so both sums-of-squares ride ONE
    "sep" all-reduce instead of two — the dynamic driver's residual test
    (||X1 - X0||_F vs ||X1||_F) is the caller, once peeled and once per
    while-loop body, so the fusion removes one collective per iteration
    from the convergence-check critical path.
    """
    return jnp.sqrt(jnp.stack([jnp.sum(jnp.abs(a) ** 2),
                               jnp.sum(jnp.abs(b) ** 2)]))


def sigma_max_upper(a):
    """Guaranteed upper bound on sigma_max: min(sqrt(||A||_1 ||A||_inf), ||A||_F)."""
    n1 = jnp.max(jnp.sum(jnp.abs(a), axis=-2))
    ninf = jnp.max(jnp.sum(jnp.abs(a), axis=-1))
    return jnp.minimum(jnp.sqrt(n1 * ninf), frobenius(a))


def sigma_max_power(a, iters: int = 10, key=None):
    """Power iteration on A^T A; sharp estimate of sigma_max (lower-biased,
    so callers wanting a bound should multiply by a safety factor)."""
    m, n = a.shape[-2:]
    if key is None:
        key = jax.random.PRNGKey(0)
    v = jax.random.normal(key, a.shape[:-2] + (n,), dtype=a.dtype)
    v = v / jnp.linalg.norm(v, axis=-1, keepdims=True)

    def body(_, v):
        w = jnp.einsum("...mn,...n->...m", a, v)
        u = jnp.einsum("...mn,...m->...n", a, w)
        return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True),
                               jnp.finfo(a.dtype).tiny)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.linalg.norm(jnp.einsum("...mn,...n->...m", a, v), axis=-1)


def sigma_min_lower(x, iters: int = 8, safety: float = 0.5, *, gram=None):
    """Deflated estimate of sigma_min(X) for X with sigma_max <= ~1.

    Inverse power iteration on G = X^T X + delta I via one Cholesky,
    delta = n * eps keeps the factorization well-posed even for singular X.
    Never returns below sqrt(delta) * safety (the resolution floor).

    The Gram product accumulates in f32-or-better and the iteration runs
    in that dtype (its eps sets the ridge): a bf16/f16 input would
    otherwise push the resolution floor to sqrt(n * eps_bf16) ~ 0.5 —
    an *over*-estimate of sigma_min, invalidating the Zolotarev interval
    it feeds.  Returns the promoted dtype (f32 for bf16/f16 inputs).

    ``gram`` swaps the Gram product for an injectable implementation
    with the :class:`repro.core.zolo.ZoloOps` ``gram(x)`` contract
    (f32-or-better accumulation).  This is how the grouped dynamic
    driver estimates the bound *sep-collectively in-graph*: ``x`` is
    then each device's (m/sep, n) row block, the collective ``gram``
    psums the partial product to the global (n, n) Gram, and everything
    after it (the n x n Cholesky and the length-n inverse-power
    iteration) is replicated per device — exactly the CholeskyQR
    distribution structure of the iteration itself.
    """
    n = x.shape[-1]
    dtype = jnp.promote_types(x.dtype, jnp.float32)
    eps = jnp.finfo(dtype).eps
    delta = n * eps
    if gram is None:
        g = jnp.einsum("...mk,...mn->...kn", x, x,
                       preferred_element_type=dtype)
    else:
        g = gram(x).astype(dtype)
    g = g + delta * jnp.eye(n, dtype=dtype)
    l = jnp.linalg.cholesky(g)

    def solve(v):
        y = jax.lax.linalg.triangular_solve(
            l, v[..., None], left_side=True, lower=True)
        z = jax.lax.linalg.triangular_solve(
            l, y, left_side=True, lower=True, transpose_a=True)
        return z[..., 0]

    v = jnp.ones(x.shape[:-2] + (n,), dtype=dtype) / jnp.sqrt(n).astype(dtype)

    def body(_, v):
        w = solve(v)
        return w / jnp.maximum(jnp.linalg.norm(w, axis=-1, keepdims=True),
                               jnp.finfo(dtype).tiny)

    v = jax.lax.fori_loop(0, iters, body, v)
    lam = jnp.einsum("...n,...n->...", v, jnp.einsum("...kn,...n->...k", g, v))
    sig2 = jnp.maximum(lam - delta, delta)
    return safety * jnp.sqrt(sig2)


def sigma_min_lower_qr(x, iters: int = 12, safety: float = 0.5):
    """sigma_min lower estimate via one QR + inverse iteration on R.

    Unlike the Gram route this never squares the condition number, so it
    resolves sigma_min down to ~eps * sigma_max (the standard trick in
    production QDWH implementations: condition-estimate the R factor).

    bf16/f16 inputs promote to f32 up front (QR has no low-precision
    kernel, and the estimate would be meaningless at eps_bf16 anyway);
    like :func:`sigma_min_lower`, the result is the promoted dtype.
    """
    n = x.shape[-1]
    dtype = jnp.promote_types(x.dtype, jnp.float32)
    x = x.astype(dtype)
    r = jnp.linalg.qr(x, mode="r")

    def solve(v):
        # w = R^{-1} R^{-T} v  (power iteration on (R^T R)^{-1})
        y = jax.lax.linalg.triangular_solve(
            r, v[..., None], left_side=True, lower=False, transpose_a=True)
        z = jax.lax.linalg.triangular_solve(
            r, y, left_side=True, lower=False)
        return z[..., 0]

    v = jnp.ones(x.shape[:-2] + (n,), dtype=dtype) / jnp.sqrt(n).astype(dtype)

    def body(_, v):
        w = solve(v)
        return w / jnp.maximum(jnp.linalg.norm(w, axis=-1, keepdims=True),
                               jnp.finfo(dtype).tiny)

    v = jax.lax.fori_loop(0, iters, body, v)
    mu = jnp.linalg.norm(solve(v), axis=-1)  # ~ 1 / sigma_min^2
    sig = 1.0 / jnp.sqrt(jnp.maximum(mu, jnp.finfo(dtype).tiny))
    eps = jnp.finfo(dtype).eps
    # an exactly singular R (every zero-padded serving slot) sends the
    # triangular solves to inf/NaN, and NaN would otherwise propagate
    # straight through maximum() into the Zolotarev coefficients; the
    # honest lower bound there is the floor itself (f(0) = 0 keeps the
    # null block exact through the iteration)
    sig = jnp.where(jnp.isfinite(sig), sig, jnp.asarray(0.0, dtype))
    return jnp.maximum(safety * sig, 4 * eps)


def singular_interval(a, iters: int = 8):
    """(lower, upper) bracket of the singular spectrum of ``a``.

    ``upper`` is the guaranteed :func:`sigma_max_upper` bound; ``lower``
    the deflated :func:`sigma_min_lower` estimate of the pre-scaled
    matrix, mapped back to the original scale.  This is the shift-
    selection seed of the spectral divide-and-conquer frontend
    (:mod:`repro.spectral.dnc`): every spectrum-splitting shift lives in
    [lower**2, upper**2] on the Gram's eigenvalue axis, so the bracket
    bounds its bisection.  Both ends are in-graph scalars (promoted to
    f32-or-better by the sigma_min route).
    """
    upper = sigma_max_upper(a)
    safe = jnp.maximum(upper, jnp.finfo(a.dtype).tiny)
    x0 = a / safe.astype(a.dtype)
    lower = sigma_min_lower(x0, iters=iters) * safe
    return lower, upper


def condition_estimate(a, iters: int = 12):
    """kappa_2 estimate: (upper bound on sigma_max) / (lower bound on
    sigma_min), i.e. an over-estimate — safe to feed the Zolotarev
    interval [1/kappa, 1].

    Routes sigma_min through the QR estimator: the Gram route squares
    the condition number and floors out near sqrt(n * eps), silently
    capping the estimate around 1e7 in f64 — useless at the paper's
    ill-conditioned regimes (kappa up to 1e16, Tables 5/10).
    """
    amax = sigma_max_upper(a)
    x0 = a / amax.astype(a.dtype)
    smin = sigma_min_lower_qr(x0, iters=iters)
    return 1.0 / smin
