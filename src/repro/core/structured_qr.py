"""Structured blocked Householder QR of M = [X; sqrt(c) I]  (paper §3.1).

The paper's MPDGEQRF observation: the bottom identity block is sparse, so
Householder panels need only m+NB rows instead of m+n.  In the stacked
layout M = [X; sqrt(c) I] ((m+n) x n) the support of panel p — the still-
active X rows [p*NB, m) plus the identity rows [0, (p+1)*NB) that carry
fill-in — is the *contiguous row window* [p*NB, p*NB + m + NB).  So the
whole algorithm is a sliding (m+NB)-row window over the stacked matrix:

    panel p:  W   = M[p*NB : p*NB+m+NB, :]        (static (m+NB) x n slice)
              QR of W[:, J_p]  (pivots = X rows, exactly like PDGEQRF,
                                which preserves row-wise backward
                                stability — the tiny sqrt(c) rows are
                                never promoted to pivots)
              block-reflector update of W's trailing columns
              R accumulates in X rows [0, n) as usual.

Savings vs. dense QR of the (m+n) x n stack: ~(4/3) n^3 flops in GEQRF and
the same again in the Q formation (MPDORGQR role), matching the paper's
1.18-1.51x.  Everything is jit-compatible (static block size,
``lax.fori_loop`` + static-size dynamic slices).

Stability note (validated in tests): an alternative elimination that pivots
on the identity block also has O(eps) norm-wise backward error but loses
*row-wise* backward stability — the sqrt(c) I block absorbs an absolute-eps
perturbation, which for the tiny first-iteration shifts of Zolo-PD turns
into 1e-8-level backward error of the final polar factor.  Pivoting on the
X rows (as ScaLAPACK's PDGEQRF does, row norms sorted large-to-small by
construction) keeps the final PD backward-stable; this is why the explicit-
Q MPDORGQR route matters and is reproduced here.

This is the high-accuracy path for Zolo-PD's first iteration; the TPU fast
path (shifted CholeskyQR2) lives in ``repro.core.zolo``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _householder_panel(panel):
    """Dense Householder QR of a (rows x nb) panel (LAPACK geqr2 + larft).

    Returns (v, tau, t, r_top) with v (rows, nb) the reflector columns
    (unit diagonal), t (nb, nb) the upper-triangular block-reflector factor
    such that H_1...H_nb = I - V T V^T, and r_top (nb, nb) the R block.
    """
    rows, nb = panel.shape
    dtype = panel.dtype
    idx = jnp.arange(rows)

    def col_step(j, state):
        p, v_acc, taus = state
        x = jax.lax.dynamic_index_in_dim(p, j, axis=1, keepdims=False)
        alpha = x[j]
        tail = jnp.where(idx > j, x, 0.0)
        xnorm2 = jnp.sum(tail * tail)
        sign = jnp.where(alpha >= 0, 1.0, -1.0).astype(dtype)
        beta = -sign * jnp.sqrt(alpha * alpha + xnorm2)
        denom = alpha - beta
        safe = xnorm2 > 0
        v = jnp.where(idx > j, tail / jnp.where(safe, denom, 1.0), 0.0)
        v = v.at[j].set(1.0)
        tau = jnp.where(safe, (beta - alpha) / beta, 0.0).astype(dtype)
        w = tau * (v @ p)  # (nb,)
        p = p - v[:, None] * w[None, :]
        # Column j exactly: beta on the pivot, zeros strictly below.
        newcol = jnp.where(idx == j, jnp.where(safe, beta, alpha),
                           jnp.where(idx < j, x, 0.0))
        p = jax.lax.dynamic_update_index_in_dim(p, newcol, j, axis=1)
        v_acc = jax.lax.dynamic_update_index_in_dim(v_acc, v, j, axis=1)
        taus = taus.at[j].set(tau)
        return p, v_acc, taus

    p, v, taus = jax.lax.fori_loop(
        0, nb, col_step,
        (panel, jnp.zeros((rows, nb), dtype), jnp.zeros((nb,), dtype)))

    # larft (forward, columnwise): T[:j, j] = -tau_j T[:j, :j] (V^T v_j).
    vtv = v.T @ v  # (nb, nb)
    col_ids = jnp.arange(nb)

    def t_step(j, t):
        mask = (col_ids < j).astype(dtype)
        col = -taus[j] * (t @ (vtv[:, j] * mask))
        col = col.at[j].set(taus[j])
        col = jnp.where(col_ids <= j, col, 0.0)
        return jax.lax.dynamic_update_index_in_dim(t, col, j, axis=1)

    t = jax.lax.fori_loop(0, nb, t_step, jnp.zeros((nb, nb), dtype))
    r_top = jnp.triu(p[:nb, :])
    return v, taus, t, r_top


@functools.partial(jax.jit, static_argnames=("block",))
def structured_qr_factor(x, sqrt_c, block: int = 32):
    """Blocked structured QR of [X; sqrt_c * I] via the sliding-window
    elimination described in the module docstring.

    Returns (r, v_all, t_all) where r is the n x n upper-triangular factor
    and (v_all, t_all) hold per-panel block reflectors (window-local row
    ordering) for :func:`apply_q_structured`.  Requires n % block == 0
    (drivers pad) and m >= n.
    """
    m, n = x.shape
    dtype = x.dtype
    if n % block != 0:
        raise ValueError(f"structured QR needs n padded to a multiple "
                         f"of the panel width: n={n}, block={block}")
    if m < n:
        raise ValueError(f"structured QR expects a tall X; got "
                         f"({m}, {n})")
    npanels = n // block
    nb = block
    win = m + nb
    col_idx = jnp.arange(n)

    s0 = jnp.concatenate([x, sqrt_c * jnp.eye(n, dtype=dtype)], axis=0)
    v_all0 = jnp.zeros((npanels, win, nb), dtype)
    t_all0 = jnp.zeros((npanels, nb, nb), dtype)

    def panel_step(p, state):
        s, v_all, t_all = state
        start = p * nb
        w = jax.lax.dynamic_slice(s, (start, 0), (win, n))
        panel = jax.lax.dynamic_slice(w, (0, start), (win, nb))
        v, taus, t, r_top = _householder_panel(panel)

        # Block-reflector update of the window's trailing columns.
        mask = (col_idx >= start + nb).astype(dtype)[None, :]
        vw = (v.T @ w) * mask  # (nb, n)
        w = w - v @ (t.T @ vw)
        # Panel columns exactly: R block on top, zeros below.
        panel_done = jnp.concatenate(
            [r_top, jnp.zeros((win - nb, nb), dtype)], axis=0)
        w = jax.lax.dynamic_update_slice(w, panel_done, (0, start))
        s = jax.lax.dynamic_update_slice(s, w, (start, 0))
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, v, p, axis=0)
        t_all = jax.lax.dynamic_update_index_in_dim(t_all, t, p, axis=0)
        return s, v_all, t_all

    s, v_all, t_all = jax.lax.fori_loop(
        0, npanels, panel_step, (s0, v_all0, t_all0))
    r = jnp.triu(s[:n, :])
    return r, v_all, t_all


@functools.partial(jax.jit, static_argnames=("m", "block"))
def apply_q_structured(v_all, t_all, m: int, block: int = 32):
    """Explicit thin Q = [Q1; Q2] (MPDORGQR role).

    Applies the block reflectors in reverse to the seed [I_n; 0], sliding
    the same (m+NB)-row window.  Returns (q1, q2) with q1 (m, n),
    q2 (n, n) and [X; sqrt_c I] = [q1; q2] R.
    """
    npanels, win, nb = v_all.shape
    n = npanels * nb
    dtype = v_all.dtype
    seed = jnp.concatenate(
        [jnp.eye(n, dtype=dtype), jnp.zeros((m, n), dtype)], axis=0)

    def panel_step(i, seed):
        p = npanels - 1 - i
        start = p * nb
        v = v_all[p]
        t = t_all[p]
        sw = jax.lax.dynamic_slice(seed, (start, 0), (win, n))
        sw = sw - v @ (t @ (v.T @ sw))
        return jax.lax.dynamic_update_slice(seed, sw, (start, 0))

    seed = jax.lax.fori_loop(0, npanels, panel_step, seed)
    return seed[:m], seed[m:]


def structured_qr_q1q2(x, sqrt_c, block: int = 32):
    """Q1, Q2 of the structured factorization [X; sqrt_c I] = [Q1; Q2] R,
    padding n to a multiple of ``block`` (and m up to n if column padding
    makes the X block wide) as needed."""
    m, n = x.shape
    pad = (-n) % block
    rpad = max(0, (n + pad) - m)  # keep the padded X tall
    if pad or rpad:
        x = jnp.pad(x, ((0, rpad), (0, pad)))
    _, v_all, t_all = structured_qr_factor(x, sqrt_c, block=block)
    q1, q2 = apply_q_structured(v_all, t_all, m + rpad, block=block)
    q1 = q1[:m, :n]
    q2 = q2[:n, :n]
    return q1, q2


def cholesky_qr2(x, shift_scale: float = 1.0):
    """Orthonormalize the columns of a tall ``x`` (..., m, k) by shifted
    CholeskyQR2 — a Gram + Cholesky + TRSM pass run twice, entirely
    matmul-shaped (the MXU-native orthonormalization this repo uses
    everywhere a Householder QR would be a bandwidth bottleneck).

    The eps-scaled trace shift keeps the Cholesky well-posed even when
    ``x`` is numerically rank-deficient (the extracted basis then spans
    range(x) plus arbitrary orthonormal fill — exactly what the spectral
    subspace-extraction and low-rank compression callers want).
    ``shift_scale`` scales that ridge for callers with dirtier inputs.
    """
    k = x.shape[-1]
    eps = jnp.finfo(x.dtype).eps

    def pass_(p):
        g = jnp.einsum("...mk,...mn->...kn", p, p,
                       preferred_element_type=jnp.promote_types(
                           p.dtype, jnp.float32)).astype(p.dtype)
        shift = (shift_scale * eps *
                 jnp.trace(g, axis1=-2, axis2=-1)[..., None, None])
        l = jnp.linalg.cholesky(g + shift * jnp.eye(k, dtype=p.dtype))
        return jax.lax.linalg.triangular_solve(
            l, p, left_side=False, lower=True, transpose_a=True)

    return pass_(pass_(x))


def dense_stacked_qr_q1q2(x, sqrt_c):
    """Oracle: thin QR of the dense (m+n) x n stack via jnp.linalg.qr."""
    m, n = x.shape
    stacked = jnp.concatenate([x, sqrt_c * jnp.eye(n, dtype=x.dtype)], axis=0)
    q, _ = jnp.linalg.qr(stacked)
    return q[:m], q[m:]


def structured_qr_flops(m: int, n: int, block: int) -> dict:
    """Analytic flop model: structured vs dense stacked QR (+ Q formation).

    dense geqrf of (M x n), M = m+n:  2 n^2 (M - n/3)
    dense orgqr thin:                 2 n^2 (M - n/3)  (same order)
    structured: every panel works on (m+NB) rows ->
                geqrf ~ 2 n^2 (m + NB - n'/3 ... ) ~ 2 m n^2 + O(n^2 NB)
    """
    mm = m + n
    dense_geqrf = 2.0 * n * n * (mm - n / 3.0)
    dense_orgqr = 2.0 * n * n * (mm - n / 3.0)
    struct_geqrf = 2.0 * n * n * (m + block)
    struct_orgqr = 2.0 * n * n * (m + block)
    return {
        "dense_geqrf": dense_geqrf,
        "dense_orgqr": dense_orgqr,
        "struct_geqrf": struct_geqrf,
        "struct_orgqr": struct_orgqr,
        "speedup_geqrf": dense_geqrf / struct_geqrf,
        "speedup_orgqr": dense_orgqr / struct_orgqr,
    }
