"""Solver registry: polar-decomposition and eigensolver backends.

``repro.core.svd`` dispatches *only* through this table — there is one
code path from ``polar_decompose`` / ``polar_svd`` down to a backend, and
a new solver (a Pallas kernel, a distributed variant, a debugging oracle)
plugs in with a decorator instead of another ``elif``:

    @register_polar("my_solver")
    def my_solver(a, **kw):
        ...
        return q, h_or_none, info

Backend contract: ``fn(a, **kw) -> (q, h | None, info)`` for an ``a``
already in canonical (m >= n) orientation; ``polar_svd`` passes
``want_h=True`` through ``kw``.  A spec with ``supports_grouped`` also
carries ``grouped_fn(a, *, mesh, **kw)`` routing the same contract
through r-process-group execution (paper Algorithm 3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class PolarSpec:
    """One registered polar-decomposition backend and its capabilities."""

    name: str
    fn: Callable
    # capability flags — the dispatcher consults these, never the name
    supports_grouped: bool = False  # can run over a ("zolo","sep") mesh
    requires_mesh: bool = False     # grouped-only backend: mesh= mandatory
    dynamic: bool = False           # runtime conditioning (while_loop)
    is_oracle: bool = False         # reference/debug path, not a solver
    grouped_fn: Optional[Callable] = None
    description: str = ""


@dataclasses.dataclass(frozen=True)
class EigSpec:
    """One registered symmetric eigensolver backend (the ELPA role)."""

    name: str
    fn: Callable  # fn(h, **kw) -> (w ascending, v)
    description: str = ""


_POLAR: Dict[str, PolarSpec] = {}
_EIG: Dict[str, EigSpec] = {}


def _same_origin(old: Callable, new: Callable) -> bool:
    """True when ``new`` is the same function re-created, e.g. by an
    importlib.reload of its defining module — re-registration is then a
    benign replacement, not a name collision.  Lambdas are never treated
    as same-origin: their shared ``<lambda>`` qualname would let two
    distinct implementations silently shadow each other."""
    qualname = getattr(new, "__qualname__", None)
    if qualname is None or "<lambda>" in qualname:
        return False
    return (getattr(old, "__module__", None) == getattr(new, "__module__", 0)
            and getattr(old, "__qualname__", None) == qualname)


def register_polar(name: str, *, supports_grouped: bool = False,
                   requires_mesh: bool = False, dynamic: bool = False,
                   is_oracle: bool = False, grouped_fn: Callable = None,
                   description: str = ""):
    """Decorator registering ``fn(a, **kw) -> (q, h, info)`` under ``name``."""

    def deco(fn):
        if name in _POLAR and not _same_origin(_POLAR[name].fn, fn):
            raise ValueError(f"polar solver {name!r} already registered")
        if supports_grouped and grouped_fn is None:
            raise ValueError(f"polar solver {name!r}: supports_grouped "
                             f"requires a grouped_fn")
        if requires_mesh and not supports_grouped:
            raise ValueError(f"polar solver {name!r}: requires_mesh without "
                             f"supports_grouped is unsatisfiable")
        _POLAR[name] = PolarSpec(
            name=name, fn=fn, supports_grouped=supports_grouped,
            requires_mesh=requires_mesh, dynamic=dynamic,
            is_oracle=is_oracle, grouped_fn=grouped_fn,
            description=description)
        return fn

    return deco


def register_eig(name: str, *, description: str = ""):
    """Decorator registering ``fn(h, **kw) -> (w, v)`` under ``name``."""

    def deco(fn):
        if name in _EIG and not _same_origin(_EIG[name].fn, fn):
            raise ValueError(f"eig solver {name!r} already registered")
        _EIG[name] = EigSpec(name=name, fn=fn, description=description)
        return fn

    return deco


def get_polar(name: str) -> PolarSpec:
    try:
        return _POLAR[name]
    except KeyError:
        raise ValueError(f"unknown polar method: {name!r} "
                         f"(registered: {sorted(_POLAR)})") from None


def get_eig(name: str) -> EigSpec:
    try:
        return _EIG[name]
    except KeyError:
        raise ValueError(f"unknown eig method: {name!r} "
                         f"(registered: {sorted(_EIG)})") from None


def list_polar() -> list:
    return sorted(_POLAR)


def list_eig() -> list:
    return sorted(_EIG)


def unregister_polar(name: str) -> None:
    """Remove a registration (tests / interactive reload)."""
    _POLAR.pop(name, None)


def unregister_eig(name: str) -> None:
    _EIG.pop(name, None)
