"""Solver registry: polar-decomposition and eigensolver backends.

``repro.solver`` plans and executes *only* through this table — there is
one code path from ``plan(...)`` (and the thin back-compat wrappers
``polar_decompose`` / ``polar_svd``) down to a backend, and a new solver
(a Pallas kernel, a distributed variant, a debugging oracle) plugs in
with a decorator instead of another ``elif``.

The Zolo family here is ONE iteration engine (:mod:`repro.core.zolo`)
bound along two orthogonal axes, and that (schedule source x ops
bundle) pairing is the template every new backend should follow:

* **schedule source** — ``run_schedule`` (trace-time precomputed
  coefficient list, unrolled; bound by the spec's ``plan_fn``) or
  ``run_dynamic`` (in-graph coefficients from the running lower bound,
  one executable for any conditioning; ``dynamic=True``).
* **:class:`repro.core.zolo.ZoloOps` bundle** — where the compute runs:
  default jnp, the fused Pallas kernels
  (``zolo_pallas`` / ``zolo_pallas_dynamic``,
  :mod:`repro.core.zolo_pallas`), or the sep-/zolo-collective
  distributed ops (``zolo_grouped`` / ``zolo_grouped_dynamic``,
  :mod:`repro.dist.grouped_ops`; ``supports_grouped=True``).

So a kernel backend injects an ops bundle into the shared engine and
registers the binding with a ``flops_fn`` that reflects where the
kernels actually run fast (compiled on TPU; Pallas interpret mode — and
a cost penalty — elsewhere), and a distributed backend composes
collective ops under a ``shard_map`` layout — neither writes a new
iteration loop:

    @register_polar("my_solver")
    def my_solver(a, **kw):
        ...
        return q, h_or_none, info

Backend contract: ``fn(a, **kw) -> (q, h | None, info)`` for an ``a``
already in canonical (m >= n) orientation; ``polar_svd`` passes
``want_h=True`` through ``kw``.  A spec with ``supports_grouped`` also
carries ``grouped_fn(a, *, mesh, **kw)`` routing the same contract
through r-process-group execution (paper Algorithm 3).

Plan-time contract (consumed by :mod:`repro.solver`):

* ``flops_fn(m, n, *, r, kappa, grouped=False, dtype=None, sep=1) ->
  float`` — total flop estimate for solving an (m, n) problem of
  condition ``kappa`` at Zolotarev order ``r``; ``grouped=True`` means
  Algorithm-3 execution (e.g. per-group Gram recomputation instead of
  the shared product) and ``sep`` is then the grouped mesh's intra-
  group distribution degree (ndev = r * sep): per-group Gram/solve work
  divides by it, with a psum communication term added, so the score is
  the true per-device cost; ``dtype`` is the plan's input dtype, so a
  backend whose cost (or fitness) depends on precision can penalize
  itself — e.g. ``zolo_pallas`` accumulates in f32 and prices itself
  out of f64 auto-selection.  When the caller supplies a measured psum
  calibration (``SvdConfig.extra["comm_flops_per_word"]``, produced by
  ``benchmarks/comm_calibrate.py``) the planner passes it as an
  additional ``comm_flops_per_word=`` keyword — a grouped cost model
  should accept and apply it (it is a scoring knob only, never a
  backend kwarg).  ``SvdConfig(method="auto")`` scores every
  capability-matching backend with this hook (grouped mode divides by r
  — the per-group critical path) and picks the cheapest; specs without
  a ``flops_fn`` rank last.  A dynamic backend should fold the price of
  "runtime" into its model (e.g. ``zolo_grouped_dynamic`` charges the
  in-graph conditioning estimate plus one safety iteration), so auto
  prefers a static schedule whenever l0 is already known and
  ``l0_policy="runtime"`` plans — where only dynamic backends are
  eligible — rank honestly among themselves.
* ``plan_fn(res) -> dict`` — called once at plan time with the resolved
  :class:`repro.solver.PlanResolution` (m, n, mode, r, l0, kappa,
  max_iters, qr_mode, qr_iters, nb); returns the *static* backend kwargs
  the plan should bind — e.g. the precomputed trace-time Zolotarev
  schedule (``{"schedule": ...}``) so repeated executions never rebuild
  it.  A ``plan_fn`` should raise ``ValueError`` for unmet plan-time
  requirements (e.g. a static schedule without ``l0``), and should
  re-emit every resolved config knob the backend accepts (those it
  names are authoritative over the caller's raw duplicates).

Caller kwargs (``SvdConfig.extra`` / legacy ``**kw``) otherwise pass
through to the backend verbatim — a kwarg the backend does not accept
fails loudly, exactly as a direct call would.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class PolarSpec:
    """One registered polar-decomposition backend and its capabilities."""

    name: str
    fn: Callable
    # capability flags — the dispatcher consults these, never the name
    supports_grouped: bool = False  # can run over a ("zolo","sep") mesh
    requires_mesh: bool = False     # grouped-only backend: mesh= mandatory
    dynamic: bool = False           # runtime conditioning (while_loop)
    is_oracle: bool = False         # reference/debug path, not a solver
    baseline: bool = False          # comparison baseline: explicit use
                                    # only, never picked by method="auto"
    grouped_fn: Optional[Callable] = None
    # plan-time hooks (see module docstring): cost model for method="auto"
    # and static-kwarg binding (precomputed schedules) for SvdPlan
    flops_fn: Optional[Callable] = None  # (m, n, *, r, kappa) -> float
    plan_fn: Optional[Callable] = None   # (PlanResolution) -> dict
    # resilience hooks (repro.resilience): the escalation ladder and the
    # runtime health verdict consult these, never the name.
    fallback: Optional[str] = None       # next-rung method when this
                                         # backend's solve fails verification
    kappa_max_f32: Optional[float] = None  # sub-f64 conditioning envelope;
                                           # runtime kappa_est beyond it is
                                           # judged unhealthy
    # per-(input dtype, accum dtype) conditioning envelope widening
    # kappa_max_f32: {("bfloat16", "float32"): 1e3, ...}.  Resolved by
    # envelope_kappa_max(); kappa_max_f32 stays the ("float32",
    # "float32") default so existing registrations keep their meaning.
    kappa_envelope: Optional[Dict] = None
    description: str = ""


@dataclasses.dataclass(frozen=True)
class EigSpec:
    """One registered symmetric eigensolver backend (the ELPA role)."""

    name: str
    fn: Callable  # fn(h, **kw) -> (w ascending, v)
    # same plan-time contract as PolarSpec, for the eig stage of Alg. 2
    flops_fn: Optional[Callable] = None  # (n, *, kappa) -> float
    plan_fn: Optional[Callable] = None   # (PlanResolution) -> dict
    description: str = ""


_POLAR: Dict[str, PolarSpec] = {}
_EIG: Dict[str, EigSpec] = {}


def _same_origin(old: Callable, new: Callable) -> bool:
    """True when ``new`` is the same function re-created, e.g. by an
    importlib.reload of its defining module — re-registration is then a
    benign replacement, not a name collision.  Lambdas are never treated
    as same-origin: their shared ``<lambda>`` qualname would let two
    distinct implementations silently shadow each other."""
    qualname = getattr(new, "__qualname__", None)
    if qualname is None or "<lambda>" in qualname:
        return False
    return (getattr(old, "__module__", None) == getattr(new, "__module__", 0)
            and getattr(old, "__qualname__", None) == qualname)


def register_polar(name: str, *, supports_grouped: bool = False,
                   requires_mesh: bool = False, dynamic: bool = False,
                   is_oracle: bool = False, baseline: bool = False,
                   grouped_fn: Callable = None,
                   flops_fn: Callable = None, plan_fn: Callable = None,
                   fallback: Optional[str] = None,
                   kappa_max_f32: Optional[float] = None,
                   kappa_envelope: Optional[Dict] = None,
                   description: str = ""):
    """Decorator registering ``fn(a, **kw) -> (q, h, info)`` under ``name``."""

    def deco(fn):
        if name in _POLAR and not _same_origin(_POLAR[name].fn, fn):
            raise ValueError(f"polar solver {name!r} already registered")
        if supports_grouped and grouped_fn is None:
            raise ValueError(f"polar solver {name!r}: supports_grouped "
                             f"requires a grouped_fn")
        if requires_mesh and not supports_grouped:
            raise ValueError(f"polar solver {name!r}: requires_mesh without "
                             f"supports_grouped is unsatisfiable")
        if fallback == name:
            raise ValueError(f"polar solver {name!r}: fallback to itself "
                             f"would loop the escalation ladder")
        _POLAR[name] = PolarSpec(
            name=name, fn=fn, supports_grouped=supports_grouped,
            requires_mesh=requires_mesh, dynamic=dynamic,
            is_oracle=is_oracle, baseline=baseline,
            grouped_fn=grouped_fn,
            flops_fn=flops_fn, plan_fn=plan_fn,
            fallback=fallback, kappa_max_f32=kappa_max_f32,
            kappa_envelope=kappa_envelope,
            description=description)
        return fn

    return deco


def register_eig(name: str, *, flops_fn: Callable = None,
                 plan_fn: Callable = None, description: str = ""):
    """Decorator registering ``fn(h, **kw) -> (w, v)`` under ``name``."""

    def deco(fn):
        if name in _EIG and not _same_origin(_EIG[name].fn, fn):
            raise ValueError(f"eig solver {name!r} already registered")
        _EIG[name] = EigSpec(name=name, fn=fn, flops_fn=flops_fn,
                             plan_fn=plan_fn, description=description)
        return fn

    return deco


def envelope_kappa_max(spec: PolarSpec, dtype,
                       accum: str = "float32") -> Optional[float]:
    """Resolve a backend's conditioning envelope for a compute dtype.

    ``dtype`` is duck-typed (anything with ``.name`` / ``.itemsize``,
    e.g. a ``jnp.dtype`` — this module stays jax-free) and names the
    *input* precision the kernels see; ``accum`` the accumulator dtype
    (f32 for every Pallas kernel in :mod:`repro.kernels`).

    Resolution, strictest-sufficient first:

    * itemsize >= 8 — no sub-f64 envelope applies: ``None``.
    * exact ``(input, accum)`` hit in ``spec.kappa_envelope``.
    * sub-f32 input with an envelope table but no entry — fail CLOSED to
      the table's minimum: an unmeasured narrow dtype must never inherit
      a wider dtype's cap.
    * otherwise ``spec.kappa_max_f32`` (the pre-envelope behavior, so
      backends without a table are unchanged).
    """
    name = getattr(dtype, "name", str(dtype))
    itemsize = int(getattr(dtype, "itemsize", 8))
    if itemsize >= 8:
        return None
    env = spec.kappa_envelope
    if env:
        key = (name, accum)
        if key in env:
            return env[key]
        if itemsize < 4:
            return min(env.values())
    return spec.kappa_max_f32


def get_polar(name: str) -> PolarSpec:
    try:
        return _POLAR[name]
    except KeyError:
        raise ValueError(f"unknown polar method: {name!r} "
                         f"(registered: {sorted(_POLAR)})") from None


def get_eig(name: str) -> EigSpec:
    try:
        return _EIG[name]
    except KeyError:
        raise ValueError(f"unknown eig method: {name!r} "
                         f"(registered: {sorted(_EIG)})") from None


def list_polar() -> list:
    return sorted(_POLAR)


def list_eig() -> list:
    return sorted(_EIG)


def unregister_polar(name: str) -> None:
    """Remove a registration (tests / interactive reload)."""
    _POLAR.pop(name, None)


def unregister_eig(name: str) -> None:
    _EIG.pop(name, None)
