"""SVD via polar decomposition + symmetric eigendecomposition.

Paper Algorithm 2 (Zolo-SVD) and its QDWH-SVD sibling:

    1.  A = Q_p H          (Zolo-PD / QDWH-PD / scaled Newton)
    2.  H = V diag(w) V^T  (eigh or block-Jacobi; the ELPA role)
    3.  U = Q_p V,  sigma = w  (descending)

plus the direct baselines: ``jnp.linalg.svd`` (the PDGESVD role) and a
one-sided (Hestenes) block-Jacobi SVD.
"""

from __future__ import annotations

import functools
import types
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import coeffs as _coeffs
from repro.core import eig as _eig
from repro.core import newton as _newton
from repro.core import norms as _norms
from repro.core import qdwh as _qdwh
from repro.core import zolo as _zolo
from repro.core import zolo_pallas as _zolo_pallas
from repro.core import registry as _registry
from repro.core.registry import register_eig, register_polar


# --- backend registrations --------------------------------------------------
# Every solver reaches plan() / polar_decompose / polar_svd through the
# registry below; there is no other dispatch.  New backends (Pallas
# kernels, alternative distributed schemes) register here or in their own
# module.  flops_fn / plan_fn are the plan-time hooks repro.solver
# consumes for method="auto" scoring and schedule precomputation (see the
# registry module docstring for the contract).


def _grouped_zolo_adapter(a, *, mesh, l0=None, r=None, want_h: bool = False,
                          hermitian_source=None, schedule=None, **kw):
    """Route the (q, h, info) contract through Algorithm-3 grouped
    execution, accepting the same kwargs as ``zolo_pd_static`` plus a
    plan-precomputed ``schedule``.  Imported lazily: core must not depend
    on repro.dist."""
    from repro.dist import grouped as _grouped

    if l0 is None and schedule is None:
        raise ValueError("grouped zolo execution needs a static l0= or a "
                         "plan-built schedule=")
    q, info = _grouped.grouped_zolo_pd_static(a, mesh=mesh, l0=l0, r=r,
                                              schedule=schedule,
                                              return_info=True, **kw)
    src = a if hermitian_source is None else hermitian_source
    h = _qdwh.form_h(q, src) if want_h else None
    return q, h, info


def _grouped_zolo_dynamic_adapter(a, *, mesh, want_h: bool = False,
                                  hermitian_source=None, **kw):
    """(q, h, info) contract over the runtime-conditioning Algorithm-3
    driver: the sigma_min bound is estimated sep-collectively in-graph
    and feeds in-graph Zolotarev coefficients, so one compiled
    executable serves any conditioning on the (r, sep) mesh."""
    from repro.dist import grouped as _grouped

    q, info = _grouped.grouped_zolo_pd_dynamic(a, mesh=mesh,
                                               return_info=True, **kw)
    src = a if hermitian_source is None else hermitian_source
    h = _qdwh.form_h(q, src) if want_h else None
    return q, h, info


# --- plan-time cost models (flops_fn) ---------------------------------------
# The Zolotarev models are seeded from repro.dist.grouped's flop
# accounting (lazy import: core must not depend on repro.dist at import).


def _zolo_flops(m, n, *, r, kappa, grouped=False, dtype=None, sep=1,
                comm_flops_per_word=None):
    from repro.dist.grouped import grouped_iteration_flops

    iters = _coeffs.zolo_iter_count(float(kappa), int(r))
    # single-address-space execution shares the Gram product across the r
    # terms; grouped (Alg. 3) execution recomputes it per group, with the
    # per-group work distributed over the mesh's sep axis (None comm
    # calibration resolves to the default prior downstream)
    return grouped_iteration_flops(m, n, int(r), iters,
                                   gram_shared=not grouped,
                                   sep=int(sep) if grouped else 1,
                                   comm_flops_per_word=comm_flops_per_word)


def _zolo_grouped_dynamic_flops(m, n, *, r, kappa, grouped=False,
                                dtype=None, sep=1,
                                comm_flops_per_word=None):
    """Cost model for the runtime-conditioning grouped backend.

    Same iteration arithmetic as the static grouped schedule, plus what
    "dynamic" actually buys and costs: the sep-collective in-graph
    conditioning estimate (one distributed Gram + the replicated n^3/3
    Cholesky and ~8 O(n^2) inverse-power solves) and one extra safety
    iteration (the deflated runtime bound under-estimates sigma_min by
    its 0.5 safety factor, which at Zolotarev rates costs at most one
    more map).  The margin keeps ``method="auto"`` on the static
    schedule whenever l0 is already known at plan time, while
    ``l0_policy="runtime"`` plans — where static backends are not
    eligible — score the dynamic backends honestly against each other.
    """
    sep_eff = int(sep) if grouped else 1
    iters = _coeffs.zolo_iter_count(float(kappa), int(r)) + 1
    from repro.dist.grouped import grouped_iteration_flops

    base = grouped_iteration_flops(m, n, int(r), iters,
                                   gram_shared=not grouped, sep=sep_eff,
                                   comm_flops_per_word=comm_flops_per_word)
    estimate = 2.0 * m * n * n / sep_eff + n ** 3 / 3.0 + 8 * 2.0 * n * n
    # the estimate runs once but every group pays it (replicated over
    # "zolo"), matching the summed-over-groups basis of the base model
    return base + (int(r) if grouped else 1) * estimate


# The recorded Pallas f32 NaN envelope (ROADMAP item 4a): the kernels
# accumulate the shifted Gram A^T A + c_j I in f32, and past this
# conditioning the smallest Zolotarev shift no longer keeps it positive
# definite at f32 resolution — the Cholesky factor silently goes NaN.
# Measured edge (n=256, geometric spectrum, r=2): clean at kappa = 2e4,
# NaN from 3e4 on; the ceiling sits at the last clean decade so a plan
# fails loudly *before* the breakdown instead of at it.
#
# Since the kernel-side shift clamp landed (ROADMAP 4a: the shifted-Gram
# c is ridged against eps(f32) * max diag G inside the kernel) the f32
# path stays finite and orth-clean well past 2e4 (measured through 1e6),
# so the cap below is now an *accuracy* contract rather than a NaN
# cliff — it is kept at the recorded value because the envelope is what
# plans, judges, and tests all key on.
PALLAS_F32_KAPPA_MAX = 2.0e4

# bf16-input kernels (f32 accumulation) envelope, measured on the same
# n=256 geometric-spectrum sweep through the Pallas static path: the
# factors stay at bf16-native accuracy (orth ~ eps_bf16, top-half
# singular values within ~2 eps_bf16 relative) through kappa = 1e4,
# drift to ~1.2e-2 by 1e5 and ~5e-2 by 1e6.  The cap sits at the last
# bf16-accurate decade, and deliberately at or below the f32 cap so the
# fail-closed min() rule for unmeasured narrow dtypes
# (repro.core.registry.envelope_kappa_max) can never resolve wider than
# a measured entry.
PALLAS_BF16_KAPPA_MAX = 1.0e4

# The per-(input dtype, accum dtype) envelope table.  Registered on the
# Pallas specs (kappa_envelope=) so the planner's pricing, the plan_fn
# fail-loud check, and the runtime health judge
# (repro.resilience.health.judge_plan) all resolve one table through
# repro.core.registry.envelope_kappa_max.
PALLAS_KAPPA_ENVELOPE = {
    ("float32", "float32"): PALLAS_F32_KAPPA_MAX,
    ("bfloat16", "float32"): PALLAS_BF16_KAPPA_MAX,
}

# envelope_kappa_max takes a spec-shaped object; this view lets the
# pricing helpers below resolve the table *before* (and independent of)
# the registrations at the bottom of this module.
_PALLAS_ENVELOPE_VIEW = types.SimpleNamespace(
    kappa_envelope=PALLAS_KAPPA_ENVELOPE,
    kappa_max_f32=PALLAS_F32_KAPPA_MAX)


def _pallas_kappa_cap(dtype) -> Optional[float]:
    """Conditioning cap for a Pallas compute dtype (None: no sub-f64
    cap applies), resolved through the same registry helper the health
    judge uses — one resolution rule, never two."""
    return _registry.envelope_kappa_max(_PALLAS_ENVELOPE_VIEW,
                                        jnp.dtype(dtype))


def _pallas_penalty(base, dtype):
    """The one place the Pallas kernel pricing policy lives.

    Two penalties keep auto-selection honest: off-TPU the kernels run
    in Pallas interpret mode (the kernel body executes in Python), and
    the kernels accumulate in f32, so an f64 plan would silently lose
    the precision the caller asked for — in both cases the backend
    stays scoreable (and explicitly selectable) but never wins
    ``method="auto"``.  On TPU at the requested precision the fused
    kernels cut HBM traffic (the +cI and the r-term combine stop being
    separate full-array passes), modeled as a small discount so auto
    prefers the kernel path at equal flops — and bf16 compute plans get
    the MXU's double feed rate on top (the kernels stream bf16 operands
    and accumulate f32, so the same tile schedule moves twice the
    elements per cycle), which is what makes ``method="auto"`` under
    ``compute_dtype="bfloat16"`` pick the kernel path inside its
    envelope.
    """
    penalty = 1.0
    if jax.default_backend() != "tpu":
        penalty *= 1e3  # interpret mode
    if dtype is not None and jnp.dtype(dtype).itemsize > 4:
        penalty *= 1e3  # f32-accumulating kernels on an f64 plan
    if penalty != 1.0:
        return base * penalty
    base *= 0.95  # fused-kernel HBM saving on TPU
    if dtype is not None and jnp.dtype(dtype).itemsize == 2:
        base *= 0.5  # bf16 MXU feed rate: ~2x f32 on the same tiles
    return base


def _pallas_envelope_priced(flops, kappa, dtype):
    """Price the conditioning envelope into auto scoring: a sub-f64
    plan beyond its compute dtype's :data:`PALLAS_KAPPA_ENVELOPE` cap
    would raise in the backend's plan_fn (fail-loud), so auto must
    never select it — an unpriced candidate that then errors would make
    ``method="auto"`` unusable at high conditioning on TPU.  Infinity
    keeps the spec scoreable (and explicitly plannable, where the
    plan_fn raises the real error).  ``dtype`` is the effective compute
    dtype (``compute_dtype`` when set, plan dtype otherwise), so a bf16
    compute plan is priced against the bf16 cap, not f32's."""
    if dtype is None or kappa is None:
        return flops
    cap = _pallas_kappa_cap(dtype)
    if cap is not None and float(kappa) > cap:
        return float("inf")
    return flops


def _zolo_pallas_flops(m, n, *, r, kappa, grouped=False, dtype=None, sep=1,
                       comm_flops_per_word=None):
    """``zolo_static`` arithmetic under the Pallas pricing policy."""
    return _pallas_envelope_priced(_pallas_penalty(
        _zolo_flops(m, n, r=r, kappa=kappa, grouped=grouped, sep=sep,
                    comm_flops_per_word=comm_flops_per_word), dtype),
        kappa, dtype)


def _zolo_pallas_dynamic_flops(m, n, *, r, kappa, grouped=False,
                               dtype=None, sep=1,
                               comm_flops_per_word=None):
    """``zolo``'s arithmetic under the Pallas pricing policy.

    Deliberately NOT the grouped-dynamic model: in the mode='dynamic'
    candidate pool every backend estimates its bound at runtime, so the
    estimate/safety margin would cancel — sharing ``zolo``'s base keeps
    the kernel-vs-XLA comparison apples-to-apples (on TPU at f32 the
    kernel loop wins by its fused-pass discount, exactly like
    ``zolo_pallas`` vs ``zolo_static``; off-TPU/f64 the penalties keep
    auto away).  The margin lives only where static and dynamic compete
    in one pool: the grouped candidates."""
    return _pallas_envelope_priced(_pallas_penalty(
        _zolo_flops(m, n, r=r, kappa=kappa, grouped=grouped, sep=sep,
                    comm_flops_per_word=comm_flops_per_word), dtype),
        kappa, dtype)


def _qdwh_flops(m, n, *, r, kappa, grouped=False, dtype=None, sep=1,
                comm_flops_per_word=None):
    iters = _coeffs.qdwh_iter_count(float(kappa))
    # per iteration: Gram product + n^3/3 Cholesky + two solves (the QR
    # iterations cost more, but only the leading one or two use QR)
    return iters * (2.0 * m * n * n + n ** 3 / 3.0 + 2.0 * m * n * n)


def _newton_flops(m, n, *, r, kappa, grouped=False, dtype=None, sep=1,
                  comm_flops_per_word=None):
    if m != n:
        return float("inf")  # scaled Newton needs a square nonsingular A
    # explicit pivoted-LU inverse (~2 n^3) per iteration, ~9 iterations
    return 9.0 * 2.0 * n ** 3


# --- plan-time static-kwarg binding (plan_fn) --------------------------------


def _zolo_static_planfn(res):
    """Precompute the trace-time Zolotarev schedule once, at plan time."""
    if res.l0 is None:
        raise ValueError(
            "a static Zolo schedule needs l0: set SvdConfig.l0, or "
            "l0_policy='estimate_at_plan' with a kappa= hint")
    r = res.r if res.r is not None else _coeffs.choose_r(1.0 / res.l0)
    sched = tuple(_coeffs.zolo_schedule_np(
        res.l0, r, max_iters=res.max_iters or 6))
    return {"schedule": sched,
            "qr_mode": res.qr_mode if res.qr_mode is not None
            else "cholqr2",
            "qr_iters": res.qr_iters if res.qr_iters is not None else 1}


def _qdwh_static_planfn(res):
    if res.l0 is None:
        raise ValueError(
            "a static QDWH schedule needs l0: set SvdConfig.l0, or "
            "l0_policy='estimate_at_plan' with a kappa= hint")
    kw = {"schedule": tuple(_coeffs.qdwh_schedule_np(
        res.l0, max_iters=res.max_iters or 8))}
    if res.qr_iters is not None:  # None keeps the c_k > 100 heuristic
        kw["qr_iters"] = res.qr_iters
    return kw


def _zolo_dynamic_planfn(res):
    """Shared by every dynamic Zolo binding (``zolo``,
    ``zolo_pallas_dynamic``, ``zolo_grouped_dynamic``): an explicit l0
    (or plan-time estimate) short-circuits the in-graph bound, and the
    config's ``qr_mode`` knob selects the peeled first iteration (the
    drivers' ``first_mode``).  For the grouped binding r is additionally
    pinned by the mesh's "zolo" axis."""
    kw = {}
    if res.r is not None:
        kw["r"] = res.r
    if res.l0 is not None:
        kw["l"] = res.l0
    if res.max_iters is not None:
        kw["max_iters"] = res.max_iters
    if res.qr_mode is not None:
        kw["first_mode"] = res.qr_mode
    return kw


def _qdwh_dynamic_planfn(res):
    kw = {}
    if res.l0 is not None:
        kw["l"] = res.l0
    if res.max_iters is not None:
        kw["max_iters"] = res.max_iters
    return kw


def _newton_planfn(res):
    return {"max_iters": res.max_iters} if res.max_iters is not None else {}


def _pallas_envelope_planfn(inner):
    """Wrap a Pallas binding's plan_fn with the precision-envelope check.

    Raises at plan time — not as runtime NaNs — when a Pallas backend is
    planned in sub-f64 compute precision at conditioning beyond its
    dtype's :data:`PALLAS_KAPPA_ENVELOPE` cap.  The effective compute
    dtype is ``res.compute_dtype`` when the config sets one, the plan
    dtype otherwise.  Dynamic plans without a kappa/l0 hint pass through
    (their conditioning only exists at execution time; the runtime
    health judge applies the same table there)."""

    @functools.wraps(inner)
    def planfn(res):
        compute = getattr(res, "compute_dtype", None)
        eff = jnp.dtype(compute) if compute is not None \
            else jnp.dtype(res.dtype)
        cap = _pallas_kappa_cap(eff)
        if cap is not None and res.kappa is not None \
                and float(res.kappa) > cap:
            raise ValueError(
                f"{res.method!r} planned at kappa={res.kappa:.3g} in "
                f"{eff.name}: beyond the Pallas f32 NaN envelope "
                f"(kappa <= {cap:.0e} for {eff.name} inputs — the "
                f"f32-accumulated shifted Gram loses the spectrum's "
                f"tail and accuracy silently degrades past the recorded "
                f"edge; ROADMAP item 4).  Plan in float64, lower the "
                f"kappa/l0 hint, or use a non-Pallas backend (e.g. "
                f"'zolo_static', 'zolo')")
        return inner(res)

    return planfn


register_polar("zolo", dynamic=True,
               flops_fn=_zolo_flops, plan_fn=_zolo_dynamic_planfn,
               description="dynamic Zolo-PD, in-graph coefficients")(
    _zolo.zolo_pd)
register_polar("zolo_static", supports_grouped=True,
               grouped_fn=_grouped_zolo_adapter,
               flops_fn=_zolo_flops, plan_fn=_zolo_static_planfn,
               description="trace-time Zolo-PD schedule")(
    _zolo.zolo_pd_static)
register_polar("zolo_grouped", supports_grouped=True, requires_mesh=True,
               grouped_fn=_grouped_zolo_adapter,
               flops_fn=_zolo_flops, plan_fn=_zolo_static_planfn,
               description="paper Alg. 3: one Zolotarev term per group")(
    _grouped_zolo_adapter)
register_polar("zolo_grouped_dynamic", dynamic=True, supports_grouped=True,
               requires_mesh=True,
               grouped_fn=_grouped_zolo_dynamic_adapter,
               flops_fn=_zolo_grouped_dynamic_flops,
               plan_fn=_zolo_dynamic_planfn,
               description="paper Alg. 3 with runtime conditioning: "
                           "sep-collective in-graph sigma_min bound "
                           "feeding in-graph Zolotarev coefficients — "
                           "one executable for any kappa on the "
                           "(r, sep) mesh")(
    _grouped_zolo_dynamic_adapter)
register_polar("zolo_pallas",
               flops_fn=_zolo_pallas_flops,
               plan_fn=_pallas_envelope_planfn(_zolo_static_planfn),
               fallback="zolo_static", kappa_max_f32=PALLAS_F32_KAPPA_MAX,
               kappa_envelope=PALLAS_KAPPA_ENVELOPE,
               description="Pallas kernel-backed trace-time Zolo-PD "
                           "(fused Gram + r-term combine; compiled on "
                           "TPU, interpret mode elsewhere)")(
    _zolo_pallas.zolo_pd_pallas)
register_polar("zolo_pallas_dynamic", dynamic=True,
               flops_fn=_zolo_pallas_dynamic_flops,
               plan_fn=_pallas_envelope_planfn(_zolo_dynamic_planfn),
               fallback="zolo", kappa_max_f32=PALLAS_F32_KAPPA_MAX,
               kappa_envelope=PALLAS_KAPPA_ENVELOPE,
               description="Pallas kernel-backed dynamic Zolo-PD "
                           "(in-graph coefficients; the kernel hot "
                           "loops inside the while_loop — compiled on "
                           "TPU, interpret mode elsewhere)")(
    _zolo_pallas.zolo_pd_pallas_dynamic)
register_polar("qdwh", dynamic=True,
               flops_fn=_qdwh_flops, plan_fn=_qdwh_dynamic_planfn,
               description="dynamic QDWH-PD baseline")(_qdwh.qdwh_pd)
register_polar("qdwh_static",
               flops_fn=_qdwh_flops, plan_fn=_qdwh_static_planfn,
               description="trace-time QDWH-PD schedule")(
    _qdwh.qdwh_pd_static)
# baseline=True: the explicit matrix inverse each iteration makes Newton
# the accuracy/stability baseline the paper compares against, not a
# production pick — its flop count is kappa-insensitive and would
# otherwise win method="auto" on every square problem.
register_polar("newton", dynamic=True, baseline=True,
               flops_fn=_newton_flops, plan_fn=_newton_planfn,
               description="scaled Newton PD baseline")(
    _newton.scaled_newton_pd)


@register_polar("svd", is_oracle=True,
                description="jnp.linalg.svd oracle (PDGESVD role)")
def _svd_oracle_polar(a, *, want_h: bool = True, **_):
    u, s, vh = jnp.linalg.svd(a, full_matrices=False)
    q = u @ vh
    h = (vh.swapaxes(-1, -2) * s[..., None, :]) @ vh if want_h else None
    info = _qdwh.PolarInfo(jnp.int32(0), jnp.asarray(0.0, a.dtype),
                           jnp.asarray(1.0, jnp.float32),
                           jnp.asarray(True),
                           jnp.asarray(float("nan"), jnp.float32))
    return q, h, info


@register_eig("eigh", description="LAPACK/XLA symmetric eigensolver")
def _eigh_backend(h, **_):
    return _eig.eigh(h)


@register_eig("jacobi", description="padded block-Jacobi (ELPA role)")
def _jacobi_backend(h, *, nb: int = 32, **_):
    return _eig.padded_block_jacobi_eigh(h, nb=nb)


def polar_decompose(a, method: str = "zolo", *, mesh=None, **kw):
    """Unified polar decomposition.  Returns (q, h, info) with A ~= Q H.

    Thin back-compat wrapper over the plan path: the call resolves a
    cached :class:`repro.solver.SvdPlan` for (shape, dtype, config) —
    the ONE dispatch route from any entry point to a registered backend —
    and executes its uncompiled implementation, so eager semantics and
    kwarg passthrough match the underlying driver exactly.  Heavy
    repeated traffic should hold the plan directly
    (``repro.solver.plan``) and call its compiled entry points.

    H (when requested by the backend's ``want_h``) is always the *right*
    polar factor, square with trailing dim n = a.shape[-1]: for m < n
    inputs the canonical factorization A^T = Q_w H_w is re-oriented via
    H = Q_w H_w Q_w^T, so A = Q H holds in every orientation.
    """
    import repro.solver as _solver

    pl, runtime_kw = _solver.plan_for_call(
        a.shape[-2:], a.dtype, method=method, mesh=mesh, kw=kw)
    return pl._polar_impl(a, extra=runtime_kw)


def polar_svd(a, method: str = "zolo", eig_method: str = "eigh",
              nb: int = 32, *, mesh=None, **kw):
    """SVD A = U diag(s) V^H via PD + EIG (paper Alg. 2).

    Returns (u, s, vh) with s descending — drop-in for
    ``jnp.linalg.svd(a, full_matrices=False)``.  ``mesh=`` routes the
    polar stage through grouped (Algorithm 3) execution for methods
    whose registry spec advertises ``supports_grouped``.  Like
    :func:`polar_decompose`, this is a thin wrapper over the single
    ``repro.solver`` plan path; hold an ``SvdPlan`` for repeated solves.
    """
    import repro.solver as _solver

    kw.setdefault("want_h", True)
    pl, runtime_kw = _solver.plan_for_call(
        a.shape[-2:], a.dtype, method=method, eig_method=eig_method,
        nb=nb, mesh=mesh, kw=kw)
    return pl._svd_impl(a, extra=runtime_kw)


@functools.partial(jax.jit, static_argnames=("nb", "max_sweeps"))
def jacobi_svd(a, nb: int = 32, max_sweeps: int = 16, tol=None):
    """One-sided (Hestenes) block-Jacobi SVD — direct-method baseline.

    Orthogonalizes column blocks pairwise with the same tournament
    schedule as the eigensolver.  Requires n % nb == 0 and n//nb even.
    Returns (u, s, vh), s descending.
    """
    if a.ndim != 2:
        raise ValueError(f"jacobi_svd takes one (m, n) matrix; got shape "
                         f"{a.shape}")
    m, n = a.shape
    dtype = a.dtype
    if n % nb != 0 or (n // nb) % 2 != 0:
        # ValueError (not assert) so misuse still fails under python -O
        raise ValueError(
            f"jacobi_svd needs n divisible by nb with an even block "
            f"count; got a.shape={tuple(a.shape)}, nb={nb} "
            f"(n % nb = {n % nb}, n // nb = {n // nb})")
    b = n // nb
    sched = jnp.asarray(_eig.round_robin_schedule(b))
    tol = tol if tol is not None else 30 * float(jnp.finfo(dtype).eps)

    def do_round(carry, pairs):
        x, v = carry
        p, q = pairs[:, 0], pairs[:, 1]
        col_ids = jnp.concatenate(
            [p[:, None] * nb + jnp.arange(nb)[None, :],
             q[:, None] * nb + jnp.arange(nb)[None, :]], axis=1)
        flat = col_ids.reshape(-1)
        blocks = x[:, flat].reshape(m, -1, 2 * nb).swapaxes(0, 1)
        acc = jnp.promote_types(dtype, jnp.float32)
        gram = jnp.einsum("pmi,pmj->pij", blocks, blocks,
                          preferred_element_type=acc).astype(dtype)
        _, j = jnp.linalg.eigh(gram)
        # descending eigenvalue order keeps big columns first (stability)
        j = j[:, :, ::-1]
        blocks_new = jnp.einsum("pmi,pij->pmj", blocks, j,
                                preferred_element_type=acc).astype(dtype)
        x = x.at[:, flat].set(blocks_new.swapaxes(0, 1).reshape(m, -1))
        vblocks = v[:, flat].reshape(n, -1, 2 * nb).swapaxes(0, 1)
        vnew = jnp.einsum("pni,pij->pnj", vblocks, j)
        v = v.at[:, flat].set(vnew.swapaxes(0, 1).reshape(n, -1))
        return (x, v), None

    def off_measure(x):
        g = x.T @ x
        d = jnp.sqrt(jnp.maximum(jnp.diag(g), jnp.finfo(dtype).tiny))
        gn = g / jnp.outer(d, d)
        return jnp.sqrt(jnp.sum(jnp.tril(gn, -1) ** 2)) / n

    def body(state):
        x, v, s, off = state
        (x, v), _ = jax.lax.scan(do_round, (x, v), sched)
        return x, v, s + 1, off_measure(x)

    def cond(state):
        _, _, s, off = state
        return jnp.logical_and(s < max_sweeps, off > tol)

    x, v, _, _ = jax.lax.while_loop(
        cond, body, (a, jnp.eye(n, dtype=dtype), jnp.int32(0),
                     jnp.asarray(1.0, dtype)))
    s = jnp.linalg.norm(x, axis=0)
    order = jnp.argsort(-s)
    s = s[order]
    u = x[:, order] / jnp.maximum(s[None, :], jnp.finfo(dtype).tiny)
    vh = v[:, order].T
    return u, s, vh


def svd_residual(a, u, s, vh):
    """Paper eq. (13): ||A - U diag(s) V^H||_F / ||A||_2."""
    rec = jnp.einsum("...mk,...kn->...mn", u * s[..., None, :], vh)
    a2 = _norms.sigma_max_power(a, iters=20)
    return _norms.frobenius(a - rec) / a2


def orthogonality(q):
    """||I - Q^H Q||_F / n (paper's OrthL/OrthR)."""
    n = q.shape[-1]
    g = jnp.einsum("...mk,...mn->...kn", q, q)
    return _norms.frobenius(g - jnp.eye(n, dtype=q.dtype)) / n
