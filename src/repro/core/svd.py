"""SVD via polar decomposition + symmetric eigendecomposition.

Paper Algorithm 2 (Zolo-SVD) and its QDWH-SVD sibling:

    1.  A = Q_p H          (Zolo-PD / QDWH-PD / scaled Newton)
    2.  H = V diag(w) V^T  (eigh or block-Jacobi; the ELPA role)
    3.  U = Q_p V,  sigma = w  (descending)

plus the direct baselines: ``jnp.linalg.svd`` (the PDGESVD role) and a
one-sided (Hestenes) block-Jacobi SVD.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import eig as _eig
from repro.core import newton as _newton
from repro.core import norms as _norms
from repro.core import qdwh as _qdwh
from repro.core import zolo as _zolo


def polar_decompose(a, method: str = "zolo", **kw):
    """Unified polar decomposition dispatcher.  Returns (q, h, info)."""
    a_work, transposed = _zolo.polar_canonical(a)
    if method == "zolo":
        q, h, info = _zolo.zolo_pd(a_work, **kw)
    elif method == "zolo_static":
        q, h, info = _zolo.zolo_pd_static(a_work, **kw)
    elif method == "qdwh":
        q, h, info = _qdwh.qdwh_pd(a_work, **kw)
    elif method == "qdwh_static":
        q, h, info = _qdwh.qdwh_pd_static(a_work, **kw)
    elif method == "newton":
        q, h, info = _newton.scaled_newton_pd(a_work, **kw)
    elif method == "svd":  # oracle
        u, s, vh = jnp.linalg.svd(a_work, full_matrices=False)
        q = u @ vh
        h = (vh.swapaxes(-1, -2) * s[..., None, :]) @ vh
        info = _qdwh.PolarInfo(jnp.int32(0), jnp.asarray(0.0, a.dtype),
                               jnp.asarray(1.0, jnp.float32))
    else:
        raise ValueError(f"unknown polar method: {method}")
    if transposed:
        q = jnp.swapaxes(q, -1, -2)
        # For A (m < n): A = Q H_right with H_right acting on the right;
        # callers that need H for the SVD use the canonical orientation.
    return q, h, info


def polar_svd(a, method: str = "zolo", eig_method: str = "eigh",
              nb: int = 32, **kw):
    """SVD A = U diag(s) V^H via PD + EIG (paper Alg. 2).

    Returns (u, s, vh) with s descending — drop-in for
    ``jnp.linalg.svd(a, full_matrices=False)``.
    """
    a_work, transposed = _zolo.polar_canonical(a)
    kw.setdefault("want_h", True)
    if method == "zolo":
        q, h, _ = _zolo.zolo_pd(a_work, **kw)
    elif method == "zolo_static":
        q, h, _ = _zolo.zolo_pd_static(a_work, **kw)
    elif method == "qdwh":
        q, h, _ = _qdwh.qdwh_pd(a_work, **kw)
    elif method == "newton":
        q, h, _ = _newton.scaled_newton_pd(a_work, **kw)
    else:
        raise ValueError(f"unknown polar method: {method}")

    if eig_method == "eigh":
        w, v = _eig.eigh(h)
    elif eig_method == "jacobi":
        w, v = _eig.padded_block_jacobi_eigh(h, nb=nb)
    else:
        raise ValueError(f"unknown eig method: {eig_method}")

    u = jnp.einsum("...mk,...kn->...mn", q, v)
    # ascending -> descending; fold any tiny negative eigenvalue's sign
    # into U so that s >= 0.
    sign = jnp.where(w < 0, -1.0, 1.0).astype(a.dtype)
    s = jnp.abs(w)
    u = u * sign[..., None, :]
    order = jnp.argsort(-s, axis=-1)
    s = jnp.take_along_axis(s, order, axis=-1)
    u = jnp.take_along_axis(u, order[..., None, :], axis=-1)
    v = jnp.take_along_axis(v, order[..., None, :], axis=-1)
    vh = jnp.swapaxes(v, -1, -2)
    if transposed:
        # a = (u s vh)^T = v s u^T
        return vh.swapaxes(-1, -2) * 1.0, s, jnp.swapaxes(u, -1, -2)
    return u, s, vh


@functools.partial(jax.jit, static_argnames=("nb", "max_sweeps"))
def jacobi_svd(a, nb: int = 32, max_sweeps: int = 16, tol=None):
    """One-sided (Hestenes) block-Jacobi SVD — direct-method baseline.

    Orthogonalizes column blocks pairwise with the same tournament
    schedule as the eigensolver.  Requires n % nb == 0 and n//nb even.
    Returns (u, s, vh), s descending.
    """
    m, n = a.shape
    dtype = a.dtype
    assert n % nb == 0 and (n // nb) % 2 == 0
    b = n // nb
    sched = jnp.asarray(_eig.round_robin_schedule(b))
    tol = tol if tol is not None else 30 * float(jnp.finfo(dtype).eps)

    def do_round(carry, pairs):
        x, v = carry
        p, q = pairs[:, 0], pairs[:, 1]
        col_ids = jnp.concatenate(
            [p[:, None] * nb + jnp.arange(nb)[None, :],
             q[:, None] * nb + jnp.arange(nb)[None, :]], axis=1)
        flat = col_ids.reshape(-1)
        blocks = x[:, flat].reshape(m, -1, 2 * nb).swapaxes(0, 1)
        gram = jnp.einsum("pmi,pmj->pij", blocks, blocks)
        _, j = jnp.linalg.eigh(gram)
        # descending eigenvalue order keeps big columns first (stability)
        j = j[:, :, ::-1]
        blocks_new = jnp.einsum("pmi,pij->pmj", blocks, j)
        x = x.at[:, flat].set(blocks_new.swapaxes(0, 1).reshape(m, -1))
        vblocks = v[:, flat].reshape(n, -1, 2 * nb).swapaxes(0, 1)
        vnew = jnp.einsum("pni,pij->pnj", vblocks, j)
        v = v.at[:, flat].set(vnew.swapaxes(0, 1).reshape(n, -1))
        return (x, v), None

    def off_measure(x):
        g = x.T @ x
        d = jnp.sqrt(jnp.maximum(jnp.diag(g), jnp.finfo(dtype).tiny))
        gn = g / jnp.outer(d, d)
        return jnp.sqrt(jnp.sum(jnp.tril(gn, -1) ** 2)) / n

    def body(state):
        x, v, s, off = state
        (x, v), _ = jax.lax.scan(do_round, (x, v), sched)
        return x, v, s + 1, off_measure(x)

    def cond(state):
        _, _, s, off = state
        return jnp.logical_and(s < max_sweeps, off > tol)

    x, v, _, _ = jax.lax.while_loop(
        cond, body, (a, jnp.eye(n, dtype=dtype), jnp.int32(0),
                     jnp.asarray(1.0, dtype)))
    s = jnp.linalg.norm(x, axis=0)
    order = jnp.argsort(-s)
    s = s[order]
    u = x[:, order] / jnp.maximum(s[None, :], jnp.finfo(dtype).tiny)
    vh = v[:, order].T
    return u, s, vh


def svd_residual(a, u, s, vh):
    """Paper eq. (13): ||A - U diag(s) V^H||_F / ||A||_2."""
    rec = jnp.einsum("...mk,...kn->...mn", u * s[..., None, :], vh)
    a2 = _norms.sigma_max_power(a, iters=20)
    return _norms.frobenius(a - rec) / a2


def orthogonality(q):
    """||I - Q^H Q||_F / n (paper's OrthL/OrthR)."""
    n = q.shape[-1]
    g = jnp.einsum("...mk,...mn->...kn", q, q)
    return _norms.frobenius(g - jnp.eye(n, dtype=q.dtype)) / n
