"""SVD via polar decomposition + symmetric eigendecomposition.

Paper Algorithm 2 (Zolo-SVD) and its QDWH-SVD sibling:

    1.  A = Q_p H          (Zolo-PD / QDWH-PD / scaled Newton)
    2.  H = V diag(w) V^T  (eigh or block-Jacobi; the ELPA role)
    3.  U = Q_p V,  sigma = w  (descending)

plus the direct baselines: ``jnp.linalg.svd`` (the PDGESVD role) and a
one-sided (Hestenes) block-Jacobi SVD.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import eig as _eig
from repro.core import newton as _newton
from repro.core import norms as _norms
from repro.core import qdwh as _qdwh
from repro.core import registry as _registry
from repro.core import zolo as _zolo
from repro.core.registry import register_eig, register_polar


# --- backend registrations --------------------------------------------------
# Every solver reaches polar_decompose / polar_svd through the registry
# below; there is no other dispatch.  New backends (Pallas kernels,
# alternative distributed schemes) register here or in their own module.


def _grouped_zolo_adapter(a, *, mesh, l0=None, r=None, want_h: bool = False,
                          hermitian_source=None, **kw):
    """Route the (q, h, info) contract through Algorithm-3 grouped
    execution, accepting the same kwargs as ``zolo_pd_static``.
    Imported lazily: core must not depend on repro.dist."""
    from repro.dist import grouped as _grouped

    if l0 is None:
        raise ValueError("grouped zolo execution needs a static l0=")
    q, info = _grouped.grouped_zolo_pd_static(a, mesh=mesh, l0=l0, r=r,
                                              return_info=True, **kw)
    src = a if hermitian_source is None else hermitian_source
    h = _qdwh.form_h(q, src) if want_h else None
    return q, h, info


register_polar("zolo", dynamic=True,
               description="dynamic Zolo-PD, in-graph coefficients")(
    _zolo.zolo_pd)
register_polar("zolo_static", supports_grouped=True,
               grouped_fn=_grouped_zolo_adapter,
               description="trace-time Zolo-PD schedule")(
    _zolo.zolo_pd_static)
register_polar("zolo_grouped", supports_grouped=True, requires_mesh=True,
               grouped_fn=_grouped_zolo_adapter,
               description="paper Alg. 3: one Zolotarev term per group")(
    _grouped_zolo_adapter)
register_polar("qdwh", dynamic=True,
               description="dynamic QDWH-PD baseline")(_qdwh.qdwh_pd)
register_polar("qdwh_static",
               description="trace-time QDWH-PD schedule")(
    _qdwh.qdwh_pd_static)
register_polar("newton", dynamic=True,
               description="scaled Newton PD baseline")(
    _newton.scaled_newton_pd)


@register_polar("svd", is_oracle=True,
                description="jnp.linalg.svd oracle (PDGESVD role)")
def _svd_oracle_polar(a, *, want_h: bool = True, **_):
    u, s, vh = jnp.linalg.svd(a, full_matrices=False)
    q = u @ vh
    h = (vh.swapaxes(-1, -2) * s[..., None, :]) @ vh if want_h else None
    info = _qdwh.PolarInfo(jnp.int32(0), jnp.asarray(0.0, a.dtype),
                           jnp.asarray(1.0, jnp.float32))
    return q, h, info


@register_eig("eigh", description="LAPACK/XLA symmetric eigensolver")
def _eigh_backend(h, **_):
    return _eig.eigh(h)


@register_eig("jacobi", description="padded block-Jacobi (ELPA role)")
def _jacobi_backend(h, *, nb: int = 32, **_):
    return _eig.padded_block_jacobi_eigh(h, nb=nb)


def _dispatch_polar(a_work, method: str, mesh=None, **kw):
    """THE polar dispatch path — registry lookup + capability routing.

    ``a_work`` must already be canonical (m >= n).  Passing ``mesh=``
    routes to the backend's grouped (Algorithm 3) execution; backends
    without that capability reject it loudly.
    """
    spec = _registry.get_polar(method)
    if mesh is not None:
        if not spec.supports_grouped:
            raise ValueError(
                f"polar method {method!r} does not support grouped "
                f"(mesh=) execution; grouped-capable methods: "
                f"{[n for n in _registry.list_polar() if _registry.get_polar(n).supports_grouped]}")
        return spec.grouped_fn(a_work, mesh=mesh, **kw)
    if spec.requires_mesh:
        raise ValueError(f"polar method {method!r} runs grouped only; "
                         f"pass mesh=zolo_group_mesh(r)")
    return spec.fn(a_work, **kw)


def polar_decompose(a, method: str = "zolo", *, mesh=None, **kw):
    """Unified polar decomposition.  Returns (q, h, info) with A ~= Q H.

    H (when requested by the backend's ``want_h``) is always the *right*
    polar factor, square with trailing dim n = a.shape[-1]: for m < n
    inputs the canonical factorization A^T = Q_w H_w is re-oriented via
    H = Q_w H_w Q_w^T, so A = Q H holds in every orientation.
    """
    a_work, transposed = _zolo.polar_canonical(a)
    q, h, info = _dispatch_polar(a_work, method, mesh=mesh, **kw)
    if transposed:
        if h is not None:
            # A = (Q_w H_w)^T = H_w Q_w^T; right factor H = Q_w H_w Q_w^T
            # satisfies A = (Q_w^T) H with H (n, n) symmetric PSD.
            h = jnp.einsum("...ik,...kl,...jl->...ij", q, h, q)
        q = jnp.swapaxes(q, -1, -2)
    return q, h, info


def polar_svd(a, method: str = "zolo", eig_method: str = "eigh",
              nb: int = 32, *, mesh=None, **kw):
    """SVD A = U diag(s) V^H via PD + EIG (paper Alg. 2).

    Returns (u, s, vh) with s descending — drop-in for
    ``jnp.linalg.svd(a, full_matrices=False)``.  ``mesh=`` routes the
    polar stage through grouped (Algorithm 3) execution for methods
    whose registry spec advertises ``supports_grouped``.
    """
    eig_spec = _registry.get_eig(eig_method)  # fail fast on typos
    a_work, transposed = _zolo.polar_canonical(a)
    kw.setdefault("want_h", True)
    q, h, _ = _dispatch_polar(a_work, method, mesh=mesh, **kw)
    w, v = eig_spec.fn(h, nb=nb)

    u = jnp.einsum("...mk,...kn->...mn", q, v)
    # ascending -> descending; fold any tiny negative eigenvalue's sign
    # into U so that s >= 0.
    sign = jnp.where(w < 0, -1.0, 1.0).astype(a.dtype)
    s = jnp.abs(w)
    u = u * sign[..., None, :]
    order = jnp.argsort(-s, axis=-1)
    s = jnp.take_along_axis(s, order, axis=-1)
    u = jnp.take_along_axis(u, order[..., None, :], axis=-1)
    v = jnp.take_along_axis(v, order[..., None, :], axis=-1)
    vh = jnp.swapaxes(v, -1, -2)
    if transposed:
        # a = (u s vh)^T = v s u^T
        return vh.swapaxes(-1, -2), s, jnp.swapaxes(u, -1, -2)
    return u, s, vh


@functools.partial(jax.jit, static_argnames=("nb", "max_sweeps"))
def jacobi_svd(a, nb: int = 32, max_sweeps: int = 16, tol=None):
    """One-sided (Hestenes) block-Jacobi SVD — direct-method baseline.

    Orthogonalizes column blocks pairwise with the same tournament
    schedule as the eigensolver.  Requires n % nb == 0 and n//nb even.
    Returns (u, s, vh), s descending.
    """
    m, n = a.shape
    dtype = a.dtype
    assert n % nb == 0 and (n // nb) % 2 == 0
    b = n // nb
    sched = jnp.asarray(_eig.round_robin_schedule(b))
    tol = tol if tol is not None else 30 * float(jnp.finfo(dtype).eps)

    def do_round(carry, pairs):
        x, v = carry
        p, q = pairs[:, 0], pairs[:, 1]
        col_ids = jnp.concatenate(
            [p[:, None] * nb + jnp.arange(nb)[None, :],
             q[:, None] * nb + jnp.arange(nb)[None, :]], axis=1)
        flat = col_ids.reshape(-1)
        blocks = x[:, flat].reshape(m, -1, 2 * nb).swapaxes(0, 1)
        gram = jnp.einsum("pmi,pmj->pij", blocks, blocks)
        _, j = jnp.linalg.eigh(gram)
        # descending eigenvalue order keeps big columns first (stability)
        j = j[:, :, ::-1]
        blocks_new = jnp.einsum("pmi,pij->pmj", blocks, j)
        x = x.at[:, flat].set(blocks_new.swapaxes(0, 1).reshape(m, -1))
        vblocks = v[:, flat].reshape(n, -1, 2 * nb).swapaxes(0, 1)
        vnew = jnp.einsum("pni,pij->pnj", vblocks, j)
        v = v.at[:, flat].set(vnew.swapaxes(0, 1).reshape(n, -1))
        return (x, v), None

    def off_measure(x):
        g = x.T @ x
        d = jnp.sqrt(jnp.maximum(jnp.diag(g), jnp.finfo(dtype).tiny))
        gn = g / jnp.outer(d, d)
        return jnp.sqrt(jnp.sum(jnp.tril(gn, -1) ** 2)) / n

    def body(state):
        x, v, s, off = state
        (x, v), _ = jax.lax.scan(do_round, (x, v), sched)
        return x, v, s + 1, off_measure(x)

    def cond(state):
        _, _, s, off = state
        return jnp.logical_and(s < max_sweeps, off > tol)

    x, v, _, _ = jax.lax.while_loop(
        cond, body, (a, jnp.eye(n, dtype=dtype), jnp.int32(0),
                     jnp.asarray(1.0, dtype)))
    s = jnp.linalg.norm(x, axis=0)
    order = jnp.argsort(-s)
    s = s[order]
    u = x[:, order] / jnp.maximum(s[None, :], jnp.finfo(dtype).tiny)
    vh = v[:, order].T
    return u, s, vh


def svd_residual(a, u, s, vh):
    """Paper eq. (13): ||A - U diag(s) V^H||_F / ||A||_2."""
    rec = jnp.einsum("...mk,...kn->...mn", u * s[..., None, :], vh)
    a2 = _norms.sigma_max_power(a, iters=20)
    return _norms.frobenius(a - rec) / a2


def orthogonality(q):
    """||I - Q^H Q||_F / n (paper's OrthL/OrthR)."""
    n = q.shape[-1]
    g = jnp.einsum("...mk,...mn->...kn", q, q)
    return _norms.frobenius(g - jnp.eye(n, dtype=q.dtype)) / n
