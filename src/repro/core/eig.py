"""Symmetric eigensolvers for the H-factor stage (paper Alg. 2 step 2).

The paper uses ELPA (two-stage tridiagonalization).  Per DESIGN.md §3 we
supply the *role* with TPU-native solvers:

* :func:`eigh`         — ``jnp.linalg.eigh`` (XLA's TPU eigh is itself a
                         QDWH-based spectral divide-and-conquer, i.e. the
                         same algorithm family as this paper).
* :func:`block_jacobi_eigh` — two-sided block-Jacobi with a round-robin
                         (tournament) ordering: every round applies b/2
                         *disjoint* block rotations, so rounds vmap/shard
                         cleanly — the matmul-rich, loosely-coupled member
                         of the family (ELPA's scalability role).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp


def eigh(h):
    return jnp.linalg.eigh(h)


def round_robin_schedule(b: int) -> np.ndarray:
    """Tournament schedule: (b-1) rounds x (b/2) disjoint pairs covering all
    unordered pairs of {0..b-1}.  b must be even."""
    if b % 2 != 0:
        raise ValueError(f"tournament schedule needs an even block "
                         f"count; got b={b}")
    players = list(range(b))
    rounds = []
    for _ in range(b - 1):
        pairs = [(players[i], players[b - 1 - i]) for i in range(b // 2)]
        rounds.append([(min(p, q), max(p, q)) for p, q in pairs])
        players = [players[0]] + [players[-1]] + players[1:-1]
    return np.asarray(rounds)  # (b-1, b/2, 2)


def _offdiag_norm(h, nb: int):
    n = h.shape[-1]
    b = n // nb
    hb = h.reshape(b, nb, b, nb)
    mask = 1.0 - jnp.eye(b, dtype=h.dtype)[:, None, :, None]
    return jnp.sqrt(jnp.sum((hb * mask) ** 2))


@functools.partial(jax.jit, static_argnames=("nb", "max_sweeps"))
def block_jacobi_eigh(h, nb: int = 32, max_sweeps: int = 12, tol=None):
    """Two-sided block-Jacobi eigendecomposition of symmetric ``h``.

    Returns (w, v) with ``h @ v = v * w`` (ascending), like jnp.linalg.eigh.
    ``n`` must be divisible by ``nb`` and ``n // nb`` must be even
    (drivers pad with an identity corner otherwise).
    """
    n = h.shape[-1]
    dtype = h.dtype
    if n % nb != 0 or (n // nb) % 2 != 0:
        raise ValueError(
            f"block_jacobi_eigh needs n divisible by nb with an even "
            f"block count; got n={n}, nb={nb} — use "
            f"padded_block_jacobi_eigh for arbitrary n")
    b = n // nb
    sched = jnp.asarray(round_robin_schedule(b))  # (rounds, pairs, 2)
    nrounds = sched.shape[0]
    tol = tol if tol is not None else 30 * float(jnp.finfo(dtype).eps)

    def do_round(carry, pairs):
        h, v = carry
        p = pairs[:, 0]
        q = pairs[:, 1]
        # gather row indices for each pair: (npairs, 2*nb)
        row_ids = (jnp.concatenate(
            [p[:, None] * nb + jnp.arange(nb)[None, :],
             q[:, None] * nb + jnp.arange(nb)[None, :]], axis=1))
        rows = h[row_ids.reshape(-1), :].reshape(-1, 2 * nb, n)
        # subproblem S_i = rows_i[:, row_ids_i]
        sub = jnp.take_along_axis(
            rows, row_ids[:, None, :].repeat(2 * nb, axis=1), axis=2)
        sub = 0.5 * (sub + jnp.swapaxes(sub, -1, -2))
        _, j = jnp.linalg.eigh(sub)  # (npairs, 2nb, 2nb)
        acc = jnp.promote_types(dtype, jnp.float32)
        # row phase: rows <- J^T rows
        rows_new = jnp.einsum("pij,pin->pjn", j, rows,
                              preferred_element_type=acc).astype(dtype)
        h = h.at[row_ids.reshape(-1), :].set(rows_new.reshape(-1, n))
        # column phase: cols <- cols J
        cols = h[:, row_ids.reshape(-1)].reshape(n, -1, 2 * nb)
        cols = jnp.swapaxes(cols, 0, 1)  # (npairs, n, 2nb)
        cols_new = jnp.einsum("pni,pij->pnj", cols, j,
                              preferred_element_type=acc).astype(dtype)
        h = h.at[:, row_ids.reshape(-1)].set(
            jnp.swapaxes(cols_new, 0, 1).reshape(n, -1))
        # accumulate eigenvectors: V <- V J (column op)
        vcols = v[:, row_ids.reshape(-1)].reshape(n, -1, 2 * nb)
        vcols = jnp.swapaxes(vcols, 0, 1)
        vcols_new = jnp.einsum("pni,pij->pnj", vcols, j)
        v = v.at[:, row_ids.reshape(-1)].set(
            jnp.swapaxes(vcols_new, 0, 1).reshape(n, -1))
        return (h, v), None

    def sweep_body(state):
        h, v, s, off = state
        (h, v), _ = jax.lax.scan(do_round, (h, v), sched)
        off = _offdiag_norm(h, nb) / jnp.maximum(
            jnp.sqrt(jnp.sum(h * h)), jnp.finfo(dtype).tiny)
        return h, v, s + 1, off

    def sweep_cond(state):
        _, _, s, off = state
        return jnp.logical_and(s < max_sweeps, off > tol)

    v0 = jnp.eye(n, dtype=dtype)
    h, v, _, _ = jax.lax.while_loop(
        sweep_cond, sweep_body, (h, v0, jnp.int32(0), jnp.asarray(1.0, dtype)))
    w = jnp.diag(h)
    order = jnp.argsort(w)
    return w[order], v[:, order]


def padded_block_jacobi_eigh(h, nb: int = 32, max_sweeps: int = 12):
    """block_jacobi_eigh with automatic padding to (even multiple of nb)."""
    n = h.shape[-1]
    b = -(-n // nb)
    if b % 2:
        b += 1
    npad = b * nb - n
    if npad:
        # pad with an identity corner scaled beyond the spectrum so the
        # padding eigenpairs separate cleanly and are dropped afterwards.
        big = 2.0 * jnp.max(jnp.abs(h)) * n + 1.0
        hp = jnp.zeros((n + npad, n + npad), h.dtype)
        hp = hp.at[:n, :n].set(h)
        hp = hp.at[jnp.arange(n, n + npad), jnp.arange(n, n + npad)].set(big)
        w, v = block_jacobi_eigh(hp, nb=nb, max_sweeps=max_sweeps)
        return w[:n], v[:n, :n]
    return block_jacobi_eigh(h, nb=nb, max_sweeps=max_sweeps)
