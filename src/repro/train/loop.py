"""Fault-tolerant training loop.

Responsibilities beyond calling train_step:

* checkpoint/restart — resume from the newest complete checkpoint; saves
  every ``ckpt_every`` steps (async) and on SIGTERM/SIGINT (preemption).
* deterministic data — batch(step) is pure, so restart needs no data
  state (see repro.data.pipeline).
* straggler/elastic hooks — the loop is structured so a step is a pure
  (state, batch) -> (state, metrics) transition; node replacement =
  restore + replay from the last step.  Per-step "valid work" weighting
  (zero-weight contributions from rejoining replicas) is plumbed through
  ``valid_scale`` for multi-host deployments.
* metrics — JSONL log with loss/grad-norm/throughput.
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class TrainLoop:
    train_step: Callable
    data: Any  # has .batch_at(step)
    ckpt: Optional[CheckpointManager] = None
    ckpt_every: int = 100
    log_every: int = 10
    log_path: Optional[str] = None
    tokens_per_step: int = 0

    def __post_init__(self):
        self._stop = False

    def _install_signals(self):
        def handler(signum, frame):
            self._stop = True

        for sig in (signal.SIGINT, signal.SIGTERM):
            try:
                signal.signal(sig, handler)
            except ValueError:  # non-main thread
                pass

    def run(self, state, num_steps: int, jit_step=None):
        """Run up to ``num_steps`` total steps (resuming from state.step)."""
        self._install_signals()
        step_fn = jit_step or jax.jit(self.train_step, donate_argnums=(0,))
        start = int(state.step)
        log_f = open(self.log_path, "a") if self.log_path else None
        t_last = time.perf_counter()
        for step in range(start, num_steps):
            if self._stop:
                break
            batch = self.data.batch_at(step)
            state, metrics = step_fn(state, batch)
            if (step + 1) % self.log_every == 0 or step + 1 == num_steps:
                metrics = {k: float(v) for k, v in metrics.items()}
                now = time.perf_counter()
                dt = (now - t_last) / self.log_every
                t_last = now
                rec = {"step": step + 1, "sec_per_step": round(dt, 4),
                       **{k: round(v, 6) for k, v in metrics.items()}}
                if self.tokens_per_step:
                    rec["tokens_per_sec"] = round(
                        self.tokens_per_step / max(dt, 1e-9), 1)
                if log_f:
                    log_f.write(json.dumps(rec) + "\n")
                    log_f.flush()
                else:
                    print(rec, flush=True)
            if self.ckpt and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, state)
        if self.ckpt:
            self.ckpt.save(int(state.step), state, block=True)
            self.ckpt.wait()
        if log_f:
            log_f.close()
        return state

    def resume_or_init(self, init_fn, key):
        """Restore the latest checkpoint if present, else init fresh."""
        state = init_fn(key)
        if self.ckpt and self.ckpt.latest_step() is not None:
            state, step = self.ckpt.restore(state)
            print(f"[loop] resumed from step {step}", flush=True)
        return state
