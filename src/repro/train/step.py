"""Compiled train step: loss -> grads -> clip -> ZoloMuon update.

The cross-entropy is computed in sequence chunks against the (possibly
model-axis-sharded) vocabulary projection so full (b, s, vocab) logits are
never materialized — required for 256k vocabularies at seq 4k.

The paper's technique runs *inside* this step: every 2-D weight's update
is orthogonalized by Zolo-PD (repro.optim.muon).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import hint_tree
from repro.models import model as M
from repro.optim.muon import MuonConfig, ZoloMuon, muon_labels
from repro.optim.schedule import warmup_cosine


@dataclasses.dataclass
class TrainState:
    step: Any
    params: Any
    opt: Any


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.step, s.params, s.opt), None),
    lambda aux, ch: TrainState(*ch))


def train_state_axes(cfg):
    """Logical axes for the full train state (params + optimizer mirrors).

    Note: ``nu`` mirrors params *structurally*, but Muon-labelled leaves
    hold scalar placeholders — axes for those leaves are overridden to ()
    by the dry-run/launcher helpers via :func:`state_axes_for_params`.
    """
    pax = M.params_axes(cfg)
    rep = "REPLICATED"
    return TrainState(step=rep, params=pax,
                      opt={"mu": pax, "nu": pax, "count": rep})


def state_axes_for_params(cfg, params_or_abstract):
    """train_state_axes with nu-axes fixed up to match actual leaf ranks
    (scalar placeholders on Muon leaves get ())."""
    axes = train_state_axes(cfg)
    labels = muon_labels(params_or_abstract)
    nu_axes = jax.tree.map(
        lambda is_muon, ax: "REPLICATED" if is_muon else ax,
        labels, axes.opt["mu"])
    axes.opt["nu"] = nu_axes
    return axes


def chunked_ce_loss(x, w, labels, *, chunk: int = 512,
                    softcap: float = 0.0, z_loss: float = 1e-4):
    """Cross entropy over seq chunks.  x: (b, s, d); w: (d, v);
    labels: (b, s) int32 (-1 = masked)."""
    b, s, d = x.shape
    nc = max(1, -(-s // chunk))
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = x.reshape(b, nc, -1, d)
    lc = labels.reshape(b, nc, -1)

    # python-unrolled chunk loop: nc is small (s/512) and unrolling keeps
    # XLA cost analysis honest (scan bodies are costed once, not x trips)
    tot = jnp.float32(0)
    cnt = jnp.float32(0)
    for i in range(nc):
        xs = xc[:, i]
        ls = lc[:, i]
        logits = jnp.einsum("bld,dv->blv", xs, w).astype(jnp.float32)
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ls, 0)[..., None], axis=-1)[..., 0]
        mask = (ls >= 0).astype(jnp.float32)
        nll = (logz - gold + z_loss * logz * logz) * mask
        tot = tot + nll.sum()
        cnt = cnt + mask.sum()
    return tot / jnp.maximum(cnt, 1.0)


def make_train_step(cfg, muon_cfg: MuonConfig, *,
                    total_steps: int = 10_000, warmup: int = 100,
                    grad_clip: float = 1.0, aux_weight: float = 0.01,
                    schedule: Optional[Callable] = None):
    """Returns (init_state_fn(key), train_step(state, batch) -> (state,
    metrics)).  Optimizer labels are built lazily from abstract params."""

    sched = schedule or functools.partial(
        warmup_cosine, warmup=warmup, total=total_steps)

    def init_state(key):
        params = M.init_params(cfg, key)
        params = jax.tree.map(
            lambda p: p.astype(jnp.float32) if p.dtype == jnp.bfloat16
            else p, params)  # f32 masters
        opt = ZoloMuon(muon_cfg, muon_labels(params))
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          opt=opt.init(params))

    def train_step(state, batch):
        compute_dtype = jnp.dtype(cfg.dtype)

        def loss_fn(params):
            cast = jax.tree.map(
                lambda p: p.astype(compute_dtype)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)
            # pin the bf16 copies to the master sharding: FSDP all-gathers
            # then move half the bytes (bf16, not f32)
            cast = hint_tree(cast, M.params_axes(cfg))
            x, aux = M.hidden_states(cast, batch, cfg)
            w = cast["embed"].T if cfg.tie_embeddings else cast["lm_head"]
            p = cfg.num_prefix_embeds
            toks = batch["tokens"]
            x_pred = x[:, p:p + toks.shape[1] - 1]
            labels = toks[:, 1:]
            loss = chunked_ce_loss(x_pred, w, labels,
                                   softcap=cfg.logits_softcap)
            return loss + aux_weight * aux, (loss, aux)

        grads, (loss, aux) = jax.grad(loss_fn, has_aux=True)(state.params)
        # under activation hints: pin grads to the param sharding, so the
        # data-parallel reduction lowers as reduce-scatter (ZeRO-2 shape)
        # instead of all-reduce + local slice
        grads = hint_tree(grads, M.params_axes(cfg))
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        clip = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * clip.astype(g.dtype), grads)

        opt = ZoloMuon(muon_cfg, muon_labels(state.params))
        lr_scale = sched(state.step)
        params, opt_state = opt.update(grads, state.opt, state.params,
                                       lr_scale=lr_scale)
        new_state = TrainState(step=state.step + 1, params=params,
                               opt=opt_state)
        metrics = {"loss": loss, "aux_loss": aux, "grad_norm": gnorm,
                   "lr_scale": lr_scale}
        return new_state, metrics

    return init_state, train_step
