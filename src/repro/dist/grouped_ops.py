"""Collective :class:`~repro.core.zolo.ZoloOps` bundles: the grouped
(Algorithm 3) execution of the one Zolotarev engine in
:mod:`repro.core.zolo` as two composable ops layers.

* :func:`sep_reduce_ops` — the intra-group 2-D distribution of one
  Zolotarev term (the paper's per-group ScaLAPACK/SEP grid, §4).
  Inside a group, the iterate X lives as an (m/sep, n) row block per
  device.  The *only* place the term math needs the whole matrix is the
  Gram product, and CholeskyQR2's communication-avoiding structure
  makes that one collective: each device forms the partial product of
  its row block and a single ``psum`` over the "sep" axis yields the
  global ``X^T X`` (the paper's per-grid PDSYRK + DGSUM2D).  Everything
  else in the engine's term bodies — the n x n Cholesky (replicated per
  device, the standard CholeskyQR trick), the triangular solves and the
  polar update (row-local) — already operates block-row-wise, so the
  *same* iteration code runs distributed by swapping this bundle in:
  no forked math.  It also supplies the collective ``fnorm`` the
  dynamic engine's residual stopping rule needs on row-sharded
  iterates.

* :func:`zolo_term_group_ops` — the inter-group "zolo"-axis layer (the
  paper's TOP context): per-group coefficient selection for the dynamic
  engine (each group evaluates ONE term of the in-graph coefficient
  set, via ``axis_index("zolo")``) and the fused combine-with-DGSUM2D
  ``polar_update`` (each group contributes ``mhat * (xw * X + a * T)``
  with ``xw`` one-hot over groups through
  :mod:`repro.kernels.grouped_combine`, and the ``psum`` over "zolo"
  output IS the next iterate — no replicated post-psum epilogue).

Both wrap any base bundle (the default jnp ops, or the Pallas-kernel
ops of :mod:`repro.core.zolo_pallas`): the base computes the local
work, these layers add the collectives.  A grouped driver composes
``zolo_term_group_ops(sep_reduce_ops(base), ...)`` and hands the result
to the engine's :func:`~repro.core.zolo.run_schedule` /
:func:`~repro.core.zolo.run_dynamic` — the grouped backends are that
composition, not a separate loop.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import zolo as _zolo


def sep_reduce_ops(base: Optional[_zolo.ZoloOps] = None,
                   *, axis: str = "sep") -> _zolo.ZoloOps:
    """A ZoloOps bundle whose ``gram`` (and ``fnorm``) all-reduce over
    the row-shard ``axis``.

    Must run inside a ``shard_map`` body over a mesh with that axis; the
    operand of ``gram`` is the local (m/sep, n) row block and the result
    is the *global* (n, n) shifted Gram, identical on every device of
    the group.  A nonzero shift is FUSED into the collective: it is
    one-hotted onto the axis-0 shard's partial product (where the base
    gram — the Pallas kernel on TPU — also applies the shift clamp), so
    the psum output already carries ``+ c I`` and no replicated epilogue
    runs after the reduce.  ``gram_local`` stays the base implementation
    (replicated operands such as the CholeskyQR2 identity block are
    never reduced), and ``polar_update`` is row-local, so the base
    version applies to the block unchanged.  ``fnorm_pair`` fuses the
    dynamic engine's two residual norms into one length-2 psum.
    """
    base = _zolo.DEFAULT_OPS if base is None else base

    def gram(x, c=0.0):
        if isinstance(c, (int, float)) and c == 0.0:
            return jax.lax.psum(base.gram(x, 0.0), axis)
        # fused sep-psum shifted Gram: one-hot the shift onto the
        # axis-0 shard's partial product so the psum output IS
        # G + c I — no replicated post-psum +cI epilogue serializing
        # after the collective.  (A uniform shift pre-psum would add
        # c * sep to the diagonal; the one-hot adds it exactly once.)
        # The base gram's shift clamp rides along on the shard that
        # carries c.
        c_arr = jnp.asarray(c)
        w = (jax.lax.axis_index(axis) == 0).astype(c_arr.dtype)
        return jax.lax.psum(base.gram(x, w * c_arr), axis)

    def fnorm(x):
        # global Frobenius norm of the row-sharded iterate: local sum of
        # squares + one psum.  (Over "zolo" the iterate is replicated —
        # every group computes the identical value, no reduction.)
        return jnp.sqrt(jax.lax.psum(jnp.sum(jnp.abs(x) ** 2), axis))

    def fnorm_pair(a, b):
        # both residual-rule norms in ONE all-reduce: stack the two
        # local sums-of-squares and psum the length-2 vector (two
        # fnorm calls would cost two latency-bound collectives per
        # dynamic iteration)
        loc = jnp.stack([jnp.sum(jnp.abs(a) ** 2),
                         jnp.sum(jnp.abs(b) ** 2)])
        return jnp.sqrt(jax.lax.psum(loc, axis))

    return base._replace(gram=gram, fnorm=fnorm, fnorm_pair=fnorm_pair)


def zolo_term_group_ops(base: Optional[_zolo.ZoloOps] = None,
                        *, xw, combine_kernel=None,
                        axis: str = "zolo") -> _zolo.ZoloOps:
    """Wrap ``base`` with the inter-group "zolo"-axis behavior: this
    group evaluates ONE Zolotarev term and the combine is the collective.

    ``xw`` is this group's X-carry weight (one-hot over the ``axis`` —
    exactly one group carries X into the combine psum, no 1/r rescale
    rounding); ``combine_kernel`` forces (True) / suppresses (False) the
    Pallas grouped-combine kernel (None: compiled on TPU, jnp oracle
    elsewhere).  Must run inside a ``shard_map`` body over a mesh with
    the ``axis``.

    * ``polar_update`` becomes the fused combine-with-DGSUM2D: the
      group's contribution ``mhat * (xw * X + sum_j a_j T_j)`` (one
      fused pass, :mod:`repro.kernels.grouped_combine`) followed by the
      ``psum`` over ``axis`` whose output IS the next iterate.
    * ``coeff_select`` takes this group's length-1 slice of the
      in-graph (c_odd, a) coefficient arrays via ``axis_index`` — the
      dynamic engine computes all r coefficients on every device and
      selects here.  (Static grouped execution slices by data layout —
      shard_map in_specs — and never calls this; defining it anyway
      keeps one bundle serving both schedule sources.)
    """
    from repro.kernels import ops as _kops

    base = _zolo.DEFAULT_OPS if base is None else base

    def polar_update(x, t, a, mhat):
        y = _kops.grouped_combine(x, t, a, mhat, xw,
                                  use_pallas=combine_kernel)
        return jax.lax.psum(y, axis)

    def coeff_select(c_odd, a):
        j = jax.lax.axis_index(axis)
        return (jax.lax.dynamic_slice_in_dim(c_odd, j, 1),
                jax.lax.dynamic_slice_in_dim(a, j, 1))

    return base._replace(polar_update=polar_update,
                         coeff_select=coeff_select)
