"""Sep-collective :class:`~repro.core.zolo.ZoloOps`: the intra-group 2-D
distribution of one Zolotarev term (the paper's per-group ScaLAPACK/SEP
grid, §4).

Inside a group, the iterate X lives as an (m/sep, n) row block per
device.  The *only* place the term math needs the whole matrix is the
Gram product, and CholeskyQR2's communication-avoiding structure makes
that one collective: each device forms the partial product of its row
block and a single ``psum`` over the "sep" axis yields the global
``X^T X`` (the paper's per-grid PDSYRK + DGSUM2D).  Everything else in
:mod:`repro.core.zolo`'s term bodies — the n x n Cholesky (replicated
per device, the standard CholeskyQR trick), the triangular solves and
the polar update (row-local) — already operates block-row-wise, so the
*same* iteration code runs distributed by swapping this bundle in: no
forked math.

``sep_reduce_ops`` wraps any base bundle (the default jnp ops, or the
Pallas-kernel ops of :mod:`repro.core.zolo_pallas`): the base computes
the local partial product, this layer adds the collective.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import zolo as _zolo


def sep_reduce_ops(base: Optional[_zolo.ZoloOps] = None,
                   *, axis: str = "sep") -> _zolo.ZoloOps:
    """A ZoloOps bundle whose ``gram`` all-reduces over the row-shard
    ``axis``.

    Must run inside a ``shard_map`` body over a mesh with that axis; the
    operand of ``gram`` is the local (m/sep, n) row block and the result
    is the *global* (n, n) shifted Gram, identical on every device of
    the group.  ``gram_local`` stays the base implementation (replicated
    operands such as the CholeskyQR2 identity block are never reduced),
    and ``polar_update`` is row-local, so the base version applies to
    the block unchanged.
    """
    base = _zolo.DEFAULT_OPS if base is None else base

    def gram(x, c=0.0):
        # local partial product first, one psum, THEN the +cI shift —
        # shifting before the reduction would add c * sep to the
        # diagonal.
        g = jax.lax.psum(base.gram(x, 0.0), axis)
        if isinstance(c, (int, float)) and c == 0.0:
            return g
        n = x.shape[-1]
        return g + jnp.asarray(c, g.dtype) * jnp.eye(n, dtype=g.dtype)

    return _zolo.ZoloOps(gram=gram, polar_update=base.polar_update,
                         gram_local=base.gram_local)
