"""Distribution layer: logical-axis sharding + grouped (Alg. 3) Zolo-PD.

Two modules, two concerns:

* :mod:`repro.dist.sharding` — the *logical-axis* layer every subsystem
  (models, optimizer, data, launch) targets; mesh binding happens once,
  at launch, via a :class:`LogicalRules` table.
* :mod:`repro.dist.grouped` — the paper's r-process-group Zolo-PD
  (Algorithm 3) on a ("zolo", "sep") mesh via ``shard_map``: r term
  groups over "zolo", each term's rows (and Gram/QR work) distributed
  over "sep" through the sep-collective ops bundle of
  :mod:`repro.dist.grouped_ops`.

See ``src/repro/dist/README.md`` for the Algorithm-3 -> mesh mapping.
"""

from repro.dist.grouped import (
    grouped_iteration_flops,
    grouped_zolo_pd_dynamic,
    grouped_zolo_pd_static,
    zolo_group_mesh,
)
from repro.dist.grouped_ops import sep_reduce_ops, zolo_term_group_ops
from repro.dist.sharding import (
    REPLICATED,
    LogicalRules,
    activation_hints,
    arch_rules,
    current_rules,
    hint,
    hint_tree,
    logical_sharding,
    tree_shardings,
)

__all__ = [
    "REPLICATED",
    "LogicalRules",
    "activation_hints",
    "arch_rules",
    "current_rules",
    "grouped_iteration_flops",
    "grouped_zolo_pd_dynamic",
    "grouped_zolo_pd_static",
    "hint",
    "hint_tree",
    "logical_sharding",
    "sep_reduce_ops",
    "tree_shardings",
    "zolo_term_group_ops",
    "zolo_group_mesh",
]
