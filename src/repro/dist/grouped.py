"""Paper Algorithm 3: grouped Zolo-PD over r independent process groups.

The r Zolotarev terms of eq. (12) are embarrassingly parallel: term j
only needs X and its own shift c_{2j-1}.  The paper runs each term in its
own ScaLAPACK process group (BLACS contexts) and combines with DGSUM2D.
Here the same two-level decomposition is a 2-D device mesh:

    zolo  (size r)        — one *group* per Zolotarev term (the paper's
                            TOP context)
    sep   (size ndev/r)   — devices *inside* a group (the paper's SEP
                            contexts — the per-group ScaLAPACK grid).
                            The iterate X is sharded row-wise over this
                            axis, so one term's Cholesky/QR work is
                            itself distributed and per-device memory for
                            the m x n iterate is O(m n / sep).

``shard_map`` partitions the per-iteration coefficient arrays over
"zolo" and the iterate over "sep".  Each group's body computes exactly
one shifted factorization on its row blocks — the Gram product is a
local partial product + one ``psum`` over "sep"
(:func:`repro.dist.grouped_ops.sep_reduce_ops`; the paper's per-grid
PDSYRK + DGSUM2D), recomputed per group as the paper's groups do (the
single-address-space gram-*sharing* optimization lives in
:mod:`repro.core.zolo`) — and the weighted sum of terms is one ``psum``
over the "zolo" axis (the TOP-context DGSUM2D role).  That combine is
fused: each group contributes ``mhat * (xw * X + a * T)`` with ``xw``
one-hot over groups (:mod:`repro.kernels.grouped_combine`; compiled on
TPU, jnp oracle elsewhere), so the psum output *is* the next iterate
and no replicated post-psum epilogue pass remains.

The schedule is trace-time (:func:`repro.core.coeffs.zolo_schedule_np`),
matching :func:`repro.core.zolo.zolo_pd_static`: first iteration via
shifted CholeskyQR2 (the stable regime), the rest via single Cholesky.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import coeffs as _coeffs
from repro.core import zolo as _zolo
from repro.core.qdwh import PolarInfo
from repro.dist import grouped_ops as _gops


def zolo_group_mesh(r: int, devices=None) -> Mesh:
    """{"zolo": r, "sep": ndev // r} mesh over the available devices.

    "zolo" indexes the r Zolotarev-term groups (paper's TOP context);
    "sep" indexes devices within one group (paper's SEP contexts).
    """
    if devices is None:
        devices = jax.devices()
    ndev = len(devices)
    if r < 1 or ndev % r != 0:
        divisors = [d for d in range(1, ndev + 1) if ndev % d == 0]
        raise ValueError(
            f"cannot split {ndev} devices into r={r} Zolotarev groups; "
            f"r must divide the device count (valid r for {ndev} "
            f"devices: {divisors})")
    # r == ndev is valid: every group is a single device and the "sep"
    # axis has size 1 — the degenerate mesh single-device CI runs on.
    arr = np.asarray(devices).reshape(r, ndev // r)
    return Mesh(arr, ("zolo", "sep"))


_TERM_FNS = {
    "chol": _zolo.term_sum_chol,
    "cholqr2": _zolo.term_sum_cholqr2,
    "householder": _zolo.term_sum_householder,
}


def grouped_zolo_pd_static(a, *, mesh: Mesh, l0: Optional[float] = None,
                           r: Optional[int] = None, max_iters: int = 6,
                           qr_mode: str = "cholqr2", qr_iters: int = 1,
                           alpha=None, return_info: bool = False,
                           schedule=None, combine_kernel=None):
    """Grouped (Alg. 3) Zolo-PD orthogonal factor of ``a`` (m >= n).

    ``a`` must have singular values in [l0 * alpha, alpha] (alpha=1 when
    omitted, i.e. pre-scaled like :func:`repro.core.zolo.zolo_pd_static`).
    ``mesh`` must come from :func:`zolo_group_mesh` with a "zolo" axis of
    size ``r``; a "sep" axis of size > 1 distributes each term's rows
    (and its Gram/QR work) over the group's devices.  ``qr_mode`` /
    ``qr_iters`` select the stable-regime term for the first iterations
    exactly as in ``zolo_pd_static`` (qr_mode="householder" requires a
    sep axis of size 1: structured Householder QR is not row-
    distributable).  A precomputed ``schedule`` (sequence of
    :class:`repro.core.coeffs.ZoloIteration`, e.g. bound once by an
    ``SvdPlan``) takes precedence over ``l0``/``max_iters`` — the plan
    builds it at plan time and this driver only lays it out over the
    mesh.  ``combine_kernel`` forces (True) or suppresses (False) the
    Pallas grouped-combine kernel; the default (None) compiles it on TPU
    and uses the jnp path elsewhere.  Returns Q only (or (Q, PolarInfo)
    with ``return_info=True``); form H with ``repro.core.form_h(q, a)``
    (the paper forms H the same way, after the combine).
    """
    if a.ndim != 2:
        raise ValueError(f"grouped Zolo-PD takes one matrix; got {a.shape}")
    if "zolo" not in mesh.axis_names:
        raise ValueError(f"mesh has no 'zolo' axis: {mesh.axis_names}")
    if schedule is not None and not len(schedule):
        raise ValueError("schedule= is empty: nothing to iterate")
    if r is None:
        r = schedule[0].r if schedule is not None else mesh.shape["zolo"]
    if mesh.shape["zolo"] != r:
        raise ValueError(
            f"mesh 'zolo' axis has size {mesh.shape['zolo']} != r={r}")
    if qr_mode not in _TERM_FNS:
        raise ValueError(f"unknown qr_mode: {qr_mode!r} "
                         f"(one of {sorted(_TERM_FNS)})")
    has_sep = "sep" in mesh.axis_names
    nsep = int(mesh.shape["sep"]) if has_sep else 1
    if nsep > 1 and qr_mode == "householder" and qr_iters > 0:
        raise ValueError(
            "qr_mode='householder' needs the full iterate on every "
            "device (structured Householder QR is not row-distributed); "
            "use a sep=1 mesh (r == ndev) or qr_mode='cholqr2'")

    if schedule is not None:
        sched = list(schedule)
        if any(it.r != r for it in sched):
            raise ValueError(
                f"schedule order {[it.r for it in sched]} does not match "
                f"the mesh 'zolo' axis of size {r}")
    elif l0 is not None:
        sched = _coeffs.zolo_schedule_np(float(l0), r, max_iters=max_iters)
    else:
        raise ValueError("grouped Zolo-PD needs a static l0= or a "
                         "precomputed schedule=")
    coeff_dtype = jnp.promote_types(a.dtype, jnp.float32)
    # (iters, r): column j belongs to group j
    c_odd = jnp.asarray([it.c[0::2] for it in sched], coeff_dtype)
    a_wts = jnp.asarray([it.a for it in sched], coeff_dtype)
    mhats = jnp.asarray([it.mhat for it in sched], coeff_dtype)
    x0 = a if alpha is None else a / jnp.asarray(alpha, a.dtype)

    m, n = x0.shape
    # Row padding to a "sep" multiple: zero rows are exact for every step
    # (zero Gram contribution, zero solve rows, zero stays zero through
    # the combine), so pad once outside and slice after.
    m_pad = m + (-m) % nsep
    if m_pad != m:
        x0 = jnp.pad(x0, ((0, m_pad - m), (0, 0)))
    x_spec = P("sep", None) if has_sep else P()
    ops = _gops.sep_reduce_ops() if has_sep else _zolo.DEFAULT_OPS
    one = jnp.ones((1,), coeff_dtype)
    if combine_kernel is None:
        # the kernel accumulates in f32: never pick it by default for
        # wider-than-f32 inputs (the f64 parity tolerances would sink)
        combine_kernel = (jax.default_backend() == "tpu"
                          and jnp.dtype(a.dtype).itemsize <= 4)
    # pallas_call has no shard_map replication rule; the psum over
    # "zolo" establishes the out_specs replication either way, so rep
    # checking is only disabled when the kernel path actually runs
    check_rep = not combine_kernel

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(x_spec, P(None, "zolo"), P(None, "zolo"), P()),
        out_specs=x_spec, check_rep=check_rep)
    def run(x, c_grp, a_grp, mh):
        # c_grp / a_grp: (iters, 1) — this group's shift and weight per
        # iteration.  x: this device's (m_pad/sep, n) row block of the
        # iterate, replicated across groups.  Per-shard proof that the
        # sep axis is a real distribution (not replication): each device
        # holds 1/sep of the rows, so its Gram input — and its O(m n /
        # sep) memory — shrinks with the group size.
        assert x.shape == (m_pad // nsep, n), \
            (x.shape, m_pad, nsep, "iterate not row-sharded over 'sep'")
        assert c_grp.shape == (len(sched), 1) == a_grp.shape, \
            (c_grp.shape, "coefficients not split over 'zolo'")
        # exactly one group carries X into the combine psum (exact — no
        # 1/r rescale rounding), every group adds its weighted term
        xw = (jax.lax.axis_index("zolo") == 0).astype(coeff_dtype)
        for i in range(len(sched)):
            term = (_TERM_FNS[qr_mode] if i < qr_iters
                    else _zolo.term_sum_chol)
            # unit term weight: the a_j scaling is linear, so it fuses
            # into the combine kernel below instead of a separate pass
            t = term(x, c_grp[i], one, ops=ops)
            y = _fused_combine(x, t, a_grp[i], mh[i], xw,
                               use_pallas=combine_kernel)
            # DGSUM2D over groups; the psum result IS the next iterate
            x = jax.lax.psum(y, "zolo")
        return x

    q = run(x0, c_odd, a_wts, mhats)
    if m_pad != m:
        q = q[:m]
    if return_info:
        info = PolarInfo(iterations=jnp.int32(len(sched)),
                         residual=jnp.asarray(0.0, a.dtype),
                         l_final=jnp.asarray(sched[-1].l_after, jnp.float32))
        return q, info
    return q


def _fused_combine(x, t, a, mhat, xw, use_pallas=None):
    """One group's combine contribution mhat * (xw * x + a * t) through
    the grouped-combine kernel wrapper (jnp oracle off-TPU)."""
    from repro.kernels import ops as _kops

    return _kops.grouped_combine(x, t[None], a, mhat, xw,
                                 use_pallas=use_pallas)


def grouped_iteration_flops(m: int, n: int, r: int, iters: int,
                            gram_shared: bool, sep: int = 1,
                            comm_flops_per_word: float = 32.0) -> float:
    """Flops (summed over the r groups, per device within a group) of
    ``iters`` Cholesky-variant Zolotarev iterations on an m x n matrix.

    Per term: one n x n Cholesky (n^3/3; replicated on every device of
    the group — the CholeskyQR structure keeps it un-distributed) plus
    two triangular solves against the local row block (2 m n^2 / sep).
    The Gram product (2 m n^2 / sep local partial + one "sep"-axis psum
    of n^2 words) is paid once per *group* in the paper-faithful mode
    (each group owns one term and recomputes G) and once per *iteration*
    in the single-address-space gram-shared mode (sep must be 1 there:
    gram sharing is the one-address-space ablation).  Collectives are
    charged at ``comm_flops_per_word`` flop-equivalents per word: the
    n^2 "sep" Gram reduction and the (m n / sep) "zolo" combine — so the
    model prices the sep speed-up against its communication and the
    planner's grouped scoring (this total / r = the per-group critical
    path) stays honest for sep > 1 meshes.
    """
    if sep < 1:
        raise ValueError(f"sep degree must be >= 1, got {sep}")
    if gram_shared and sep != 1:
        raise ValueError("gram_shared is the single-address-space mode; "
                         "the sep axis does not apply (got sep="
                         f"{sep})")
    gram = 2.0 * m * n * n / sep
    per_term = n ** 3 / 3.0 + 2.0 * m * n * n / sep
    if gram_shared:
        per_iter = gram + r * per_term
    else:
        comm = comm_flops_per_word * (
            (float(n * n) if sep > 1 else 0.0)      # "sep" Gram psum
            + (m * n / sep if r > 1 else 0.0))      # "zolo" combine psum
        per_iter = r * (gram + per_term + comm)
    return float(iters * per_iter)
