"""Paper Algorithm 3: grouped Zolo-PD over r independent process groups.

The r Zolotarev terms of eq. (12) are embarrassingly parallel: term j
only needs X and its own shift c_{2j-1}.  The paper runs each term in its
own ScaLAPACK process group (BLACS contexts) and combines with DGSUM2D.
Here the same decomposition is a 2-D device mesh:

    zolo  (size r)        — one *group* per Zolotarev term
    sep   (size ndev/r)   — devices *inside* a group (the per-group
                            ScaLAPACK grid; spare capacity today, the
                            intra-group 2-D block distribution tomorrow)

``shard_map`` partitions the per-iteration coefficient arrays over
"zolo", so each group's body computes exactly one shifted factorization —
recomputing its own Gram matrix, as the paper's groups do (the
single-address-space gram-*sharing* optimization lives in
:mod:`repro.core.zolo`) — and the weighted sum of terms is one
``psum`` over the "zolo" axis (the DGSUM2D role).

The schedule is trace-time (:func:`repro.core.coeffs.zolo_schedule_np`),
matching :func:`repro.core.zolo.zolo_pd_static`: first iteration via
shifted CholeskyQR2 (the stable regime), the rest via single Cholesky.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import coeffs as _coeffs
from repro.core import zolo as _zolo
from repro.core.qdwh import PolarInfo


def zolo_group_mesh(r: int, devices=None) -> Mesh:
    """{"zolo": r, "sep": ndev // r} mesh over the available devices.

    "zolo" indexes the r Zolotarev-term groups (paper's TOP context);
    "sep" indexes devices within one group (paper's SEP contexts).
    """
    if devices is None:
        devices = jax.devices()
    ndev = len(devices)
    if r < 1 or ndev % r != 0:
        divisors = [d for d in range(1, ndev + 1) if ndev % d == 0]
        raise ValueError(
            f"cannot split {ndev} devices into r={r} Zolotarev groups; "
            f"r must divide the device count (valid r for {ndev} "
            f"devices: {divisors})")
    # r == ndev is valid: every group is a single device and the "sep"
    # axis has size 1 — the degenerate mesh single-device CI runs on.
    arr = np.asarray(devices).reshape(r, ndev // r)
    return Mesh(arr, ("zolo", "sep"))


_TERM_FNS = {
    "chol": _zolo.term_sum_chol,
    "cholqr2": _zolo.term_sum_cholqr2,
    "householder": _zolo.term_sum_householder,
}


def grouped_zolo_pd_static(a, *, mesh: Mesh, l0: Optional[float] = None,
                           r: Optional[int] = None, max_iters: int = 6,
                           qr_mode: str = "cholqr2", qr_iters: int = 1,
                           alpha=None, return_info: bool = False,
                           schedule=None):
    """Grouped (Alg. 3) Zolo-PD orthogonal factor of ``a`` (m >= n).

    ``a`` must have singular values in [l0 * alpha, alpha] (alpha=1 when
    omitted, i.e. pre-scaled like :func:`repro.core.zolo.zolo_pd_static`).
    ``mesh`` must come from :func:`zolo_group_mesh` with a "zolo" axis of
    size ``r``.  ``qr_mode`` / ``qr_iters`` select the stable-regime term
    for the first iterations exactly as in ``zolo_pd_static``.  A
    precomputed ``schedule`` (sequence of
    :class:`repro.core.coeffs.ZoloIteration`, e.g. bound once by an
    ``SvdPlan``) takes precedence over ``l0``/``max_iters`` — the plan
    builds it at plan time and this driver only lays it out over the
    mesh.  Returns Q only (or (Q, PolarInfo) with ``return_info=True``);
    form H with ``repro.core.form_h(q, a)`` (the paper forms H the same
    way, after the combine).
    """
    if a.ndim != 2:
        raise ValueError(f"grouped Zolo-PD takes one matrix; got {a.shape}")
    if "zolo" not in mesh.axis_names:
        raise ValueError(f"mesh has no 'zolo' axis: {mesh.axis_names}")
    if schedule is not None and not len(schedule):
        raise ValueError("schedule= is empty: nothing to iterate")
    if r is None:
        r = schedule[0].r if schedule is not None else mesh.shape["zolo"]
    if mesh.shape["zolo"] != r:
        raise ValueError(
            f"mesh 'zolo' axis has size {mesh.shape['zolo']} != r={r}")
    if qr_mode not in _TERM_FNS:
        raise ValueError(f"unknown qr_mode: {qr_mode!r} "
                         f"(one of {sorted(_TERM_FNS)})")

    if schedule is not None:
        sched = list(schedule)
        if any(it.r != r for it in sched):
            raise ValueError(
                f"schedule order {[it.r for it in sched]} does not match "
                f"the mesh 'zolo' axis of size {r}")
    elif l0 is not None:
        sched = _coeffs.zolo_schedule_np(float(l0), r, max_iters=max_iters)
    else:
        raise ValueError("grouped Zolo-PD needs a static l0= or a "
                         "precomputed schedule=")
    coeff_dtype = jnp.promote_types(a.dtype, jnp.float32)
    # (iters, r): column j belongs to group j
    c_odd = jnp.asarray([it.c[0::2] for it in sched], coeff_dtype)
    a_wts = jnp.asarray([it.a for it in sched], coeff_dtype)
    mhats = jnp.asarray([it.mhat for it in sched], coeff_dtype)
    x0 = a if alpha is None else a / jnp.asarray(alpha, a.dtype)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(None, "zolo"), P(None, "zolo"), P()),
        out_specs=P())
    def run(x, c_grp, a_grp, mh):
        # c_grp / a_grp: (iters, 1) — this group's shift and weight per
        # iteration.  x is replicated; each group recomputes its own Gram
        # inside term_sum_* (paper-faithful; no cross-group reuse).
        for i in range(len(sched)):
            term = (_TERM_FNS[qr_mode] if i < qr_iters
                    else _zolo.term_sum_chol)
            t = term(x, c_grp[i], a_grp[i])
            t = jax.lax.psum(t, "zolo")  # DGSUM2D combine over groups
            x = mh[i].astype(x.dtype) * (x + t)
        return x

    q = run(x0, c_odd, a_wts, mhats)
    if return_info:
        info = PolarInfo(iterations=jnp.int32(len(sched)),
                         residual=jnp.asarray(0.0, a.dtype),
                         l_final=jnp.asarray(sched[-1].l_after, jnp.float32))
        return q, info
    return q


def grouped_iteration_flops(m: int, n: int, r: int, iters: int,
                            gram_shared: bool) -> float:
    """Total flops (summed over all r groups) of ``iters`` Cholesky-variant
    Zolotarev iterations on an m x n matrix.

    Per term: one n x n Cholesky (n^3/3) plus two triangular solves
    against m right-hand sides (2 * m n^2).  The Gram product (2 m n^2)
    is paid once per *group* in the paper-faithful mode (each group owns
    one term and recomputes G) and once per *iteration* in the
    single-address-space gram-shared mode.  Divide by r for the per-group
    critical path in the r-way parallel setting.
    """
    gram = 2.0 * m * n * n
    per_term = n ** 3 / 3.0 + 2.0 * m * n * n
    if gram_shared:
        per_iter = gram + r * per_term
    else:
        per_iter = r * (gram + per_term)
    return float(iters * per_iter)
