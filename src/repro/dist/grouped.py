"""Paper Algorithm 3: grouped Zolo-PD over r independent process groups.

The r Zolotarev terms of eq. (12) are embarrassingly parallel: term j
only needs X and its own shift c_{2j-1}.  The paper runs each term in its
own ScaLAPACK process group (BLACS contexts) and combines with DGSUM2D.
Here the same two-level decomposition is a 2-D device mesh:

    zolo  (size r)        — one *group* per Zolotarev term (the paper's
                            TOP context)
    sep   (size ndev/r)   — devices *inside* a group (the paper's SEP
                            contexts — the per-group ScaLAPACK grid).
                            The iterate X is sharded row-wise over this
                            axis, so one term's Cholesky/QR work is
                            itself distributed and per-device memory for
                            the m x n iterate is O(m n / sep).

Both drivers here are thin ``shard_map`` bindings of the ONE iteration
engine in :mod:`repro.core.zolo`: they lay the iterate and coefficients
out over the mesh, compose the collective :class:`~repro.core.zolo.
ZoloOps` bundle (``sep_reduce_ops`` for the intra-group Gram psum —
the paper's per-grid PDSYRK + DGSUM2D — and ``zolo_term_group_ops``
for the per-group coefficient slice + fused combine whose "zolo" psum
output IS the next iterate), and hand off to the engine's loop.  There
is no grouped iteration math in this module.

* :func:`grouped_zolo_pd_static` — trace-time schedule
  (:func:`repro.core.coeffs.zolo_schedule_np`), laid out over the mesh
  by the shard_map in_specs and run by
  :func:`repro.core.zolo.run_schedule`.
* :func:`grouped_zolo_pd_dynamic` — runtime conditioning: the
  ``sigma_min`` lower bound is estimated *sep-collectively in-graph*
  (:func:`repro.core.norms.sigma_min_lower` over the collective Gram)
  and feeds :func:`repro.core.zolo.run_dynamic`'s in-graph Zolotarev
  coefficients, so ONE compiled executable serves any conditioning on
  the full (r, sep) mesh — the adaptive kappa-driven execution of the
  ROADMAP's dynamic-grouped item.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import coeffs as _coeffs
from repro.core import norms as _norms
from repro.core import zolo as _zolo
from repro.core.qdwh import PolarInfo
from repro.dist import grouped_ops as _gops


def zolo_group_mesh(r: int, devices=None) -> Mesh:
    """{"zolo": r, "sep": ndev // r} mesh over the available devices.

    "zolo" indexes the r Zolotarev-term groups (paper's TOP context);
    "sep" indexes devices within one group (paper's SEP contexts).
    """
    if devices is None:
        devices = jax.devices()
    ndev = len(devices)
    if r < 1 or ndev % r != 0:
        divisors = [d for d in range(1, ndev + 1) if ndev % d == 0]
        raise ValueError(
            f"cannot split {ndev} devices into r={r} Zolotarev groups; "
            f"r must divide the device count (valid r for {ndev} "
            f"devices: {divisors})")
    # r == ndev is valid: every group is a single device and the "sep"
    # axis has size 1 — the degenerate mesh single-device CI runs on.
    arr = np.asarray(devices).reshape(r, ndev // r)
    return Mesh(arr, ("zolo", "sep"))


def _mesh_layout(a, mesh: Mesh, r: Optional[int], qr_mode: str,
                 qr_iters: int, first_iter_modes=(),
                 mode_knob: str = "qr_mode"):
    """Shared mesh/shape validation for both grouped drivers.

    Returns (r, nsep, has_sep, m, n, m_pad, x_spec): the (r, sep)
    factorization, and the row padding to a "sep" multiple (zero rows
    are exact for every engine step: zero Gram contribution, zero solve
    rows, zero stays zero through the combine — pad once outside the
    shard_map and slice after).
    """
    if a.ndim != 2:
        raise ValueError(f"grouped Zolo-PD takes one matrix; got {a.shape}")
    if "zolo" not in mesh.axis_names:
        raise ValueError(f"mesh has no 'zolo' axis: {mesh.axis_names}")
    if r is None:
        r = mesh.shape["zolo"]
    if mesh.shape["zolo"] != r:
        raise ValueError(
            f"mesh 'zolo' axis has size {mesh.shape['zolo']} != r={r}")
    _zolo._validate_iter_mode(mode_knob, qr_mode, extra=first_iter_modes)
    has_sep = "sep" in mesh.axis_names
    nsep = int(mesh.shape["sep"]) if has_sep else 1
    if nsep > 1 and qr_mode == "householder" and qr_iters > 0:
        raise ValueError(
            f"{mode_knob}='householder' needs the full iterate on every "
            f"device (structured Householder QR is not row-distributed); "
            f"use a sep=1 mesh (r == ndev) or {mode_knob}='cholqr2'")
    m, n = a.shape
    m_pad = m + (-m) % nsep
    x_spec = P("sep", None) if has_sep else P()
    return r, nsep, has_sep, m, n, m_pad, x_spec


def _group_ops(has_sep: bool, xw, combine_kernel,
               gram_kernel: bool = False) -> _zolo.ZoloOps:
    """The grouped ZoloOps composition: intra-group sep collectives
    under the inter-group term-slice + fused combine layer.

    ``gram_kernel=True`` swaps the local base from the jnp ops to the
    Pallas-kernel bundle, so every Gram in the grouped path — the
    shared/shifted iterate Gram, the CholeskyQR2 second-pass ``g2``
    Grams (``gram(q1)`` row-sharded + ``gram_local(q2)`` replicated),
    and the dynamic driver's sigma_min Gram — runs the tiled kernel on
    the local block before the "sep" psum fuses in the shift."""
    if gram_kernel:
        from repro.core.zolo_pallas import pallas_zolo_ops
        base = pallas_zolo_ops()
    else:
        base = _zolo.DEFAULT_OPS
    if has_sep:
        base = _gops.sep_reduce_ops(base)
    return _gops.zolo_term_group_ops(base, xw=xw,
                                     combine_kernel=combine_kernel)


def _default_combine_kernel(dtype) -> bool:
    # the kernel accumulates in f32: never pick it by default for
    # wider-than-f32 inputs (the f64 parity tolerances would sink)
    return (jax.default_backend() == "tpu"
            and jnp.dtype(dtype).itemsize <= 4)


# the gram kernel follows the same policy: compiled on TPU for f32-and-
# narrower iterates, jnp elsewhere (interpret mode would run the kernel
# body in Python per device on CPU meshes)
_default_gram_kernel = _default_combine_kernel


def grouped_zolo_pd_static(a, *, mesh: Mesh, l0: Optional[float] = None,
                           r: Optional[int] = None, max_iters: int = 6,
                           qr_mode: str = "cholqr2", qr_iters: int = 1,
                           alpha=None, return_info: bool = False,
                           schedule=None, combine_kernel=None,
                           gram_kernel=None):
    """Grouped (Alg. 3) Zolo-PD orthogonal factor of ``a`` (m >= n) —
    the (static schedule, collective ops) binding of the engine.

    ``a`` must have singular values in [l0 * alpha, alpha] (alpha=1 when
    omitted, i.e. pre-scaled like :func:`repro.core.zolo.zolo_pd_static`).
    ``mesh`` must come from :func:`zolo_group_mesh` with a "zolo" axis of
    size ``r``; a "sep" axis of size > 1 distributes each term's rows
    (and its Gram/QR work) over the group's devices.  ``qr_mode`` /
    ``qr_iters`` select the stable-regime term for the first iterations
    exactly as in ``zolo_pd_static`` (qr_mode="householder" requires a
    sep axis of size 1: structured Householder QR is not row-
    distributable).  A precomputed ``schedule`` (sequence of
    :class:`repro.core.coeffs.ZoloIteration`, e.g. bound once by an
    ``SvdPlan``) takes precedence over ``l0``/``max_iters`` — the plan
    builds it at plan time and this driver only lays it out over the
    mesh.  ``combine_kernel`` forces (True) or suppresses (False) the
    Pallas grouped-combine kernel, and ``gram_kernel`` does the same for
    the Pallas gram kernel backing every local Gram (the shifted iterate
    Gram, the CholeskyQR2 second-pass ``g2``); the defaults (None)
    compile them on TPU for f32-and-narrower iterates and use the jnp
    path elsewhere.  Returns Q only (or (Q, PolarInfo)
    with ``return_info=True``); form H with ``repro.core.form_h(q, a)``
    (the paper forms H the same way, after the combine).
    """
    if schedule is not None and not len(schedule):
        raise ValueError("schedule= is empty: nothing to iterate")
    if r is None and schedule is not None:
        r = schedule[0].r
    r, nsep, has_sep, m, n, m_pad, x_spec = _mesh_layout(
        a, mesh, r, qr_mode, qr_iters)

    if schedule is not None:
        sched = list(schedule)
        if any(it.r != r for it in sched):
            raise ValueError(
                f"schedule order {[it.r for it in sched]} does not match "
                f"the mesh 'zolo' axis of size {r}")
    elif l0 is not None:
        sched = _coeffs.zolo_schedule_np(float(l0), r, max_iters=max_iters)
    else:
        raise ValueError("grouped Zolo-PD needs a static l0= or a "
                         "precomputed schedule=")
    coeff_dtype = jnp.promote_types(a.dtype, jnp.float32)
    # (iters, r): column j belongs to group j
    c_odd = jnp.asarray([it.c[0::2] for it in sched], coeff_dtype)
    a_wts = jnp.asarray([it.a for it in sched], coeff_dtype)
    mhats = jnp.asarray([it.mhat for it in sched], coeff_dtype)
    x0 = a if alpha is None else a / jnp.asarray(alpha, a.dtype)
    if m_pad != m:
        x0 = jnp.pad(x0, ((0, m_pad - m), (0, 0)))
    if combine_kernel is None:
        combine_kernel = _default_combine_kernel(a.dtype)
    if gram_kernel is None:
        gram_kernel = _default_gram_kernel(a.dtype)
    # pallas_call has no shard_map replication rule, so check_rep must be
    # False whenever ANY Pallas kernel (combine or gram) runs in the
    # body; the psum over "zolo" establishes the out_specs replication
    # either way, so rep checking is only disabled when a kernel path
    # actually runs
    check_rep = not (combine_kernel or gram_kernel)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(x_spec, P(None, "zolo"), P(None, "zolo"), P()),
        out_specs=x_spec, check_rep=check_rep)
    def run(x, c_grp, a_grp, mh):
        # c_grp / a_grp: (iters, 1) — this group's shift and weight per
        # iteration.  x: this device's (m_pad/sep, n) row block of the
        # iterate, replicated across groups.  Per-shard proof that the
        # sep axis is a real distribution (not replication): each device
        # holds 1/sep of the rows, so its Gram input — and its O(m n /
        # sep) memory — shrinks with the group size.
        if x.shape != (m_pad // nsep, n):
            raise AssertionError(
                f"iterate not row-sharded over 'sep': per-device shape "
                f"{x.shape}, expected ({m_pad // nsep}, {n}) "
                f"(m_pad={m_pad}, sep={nsep})")
        if not (c_grp.shape == (len(sched), 1) == a_grp.shape):
            raise AssertionError(
                f"coefficients not split over 'zolo': got {c_grp.shape}/"
                f"{a_grp.shape}, expected ({len(sched)}, 1)")
        # exactly one group carries X into the combine psum (exact — no
        # 1/r rescale rounding), every group adds its weighted term;
        # the engine's loop does the rest through the collective bundle
        xw = (jax.lax.axis_index("zolo") == 0).astype(coeff_dtype)
        ops = _group_ops(has_sep, xw, combine_kernel, gram_kernel)
        return _zolo.run_schedule(x, c_grp, a_grp, mh, qr_mode=qr_mode,
                                  qr_iters=qr_iters, ops=ops)

    q = run(x0, c_odd, a_wts, mhats)
    if m_pad != m:
        q = q[:m]
    if return_info:
        info = PolarInfo(iterations=jnp.int32(len(sched)),
                         residual=jnp.asarray(0.0, a.dtype),
                         l_final=jnp.asarray(sched[-1].l_after, jnp.float32),
                         converged=jnp.asarray(True),
                         l_init=jnp.asarray(sched[0].l_before, jnp.float32))
        return q, info
    return q


def grouped_zolo_pd_dynamic(a, *, mesh: Mesh, r: Optional[int] = None,
                            l=None, alpha=None, max_iters: int = 8,
                            first_mode: str = "auto",
                            eps: Optional[float] = None,
                            est_iters: int = 8,
                            return_info: bool = False,
                            combine_kernel=None, gram_kernel=None):
    """Grouped (Alg. 3) Zolo-PD with *runtime* conditioning — the
    (dynamic schedule, collective ops) binding of the engine.

    One compiled executable serves any conditioning on the full
    (r, sep) mesh: ``alpha`` defaults to the in-graph guaranteed upper
    bound :func:`repro.core.norms.sigma_max_upper`, and the lower bound
    ``l`` (when not given) is estimated *sep-collectively in-graph* —
    each device forms the partial Gram of its (m/sep, n) row block, one
    psum over "sep" yields the global Gram, and the deflated
    inverse-power estimate of :func:`repro.core.norms.sigma_min_lower`
    runs replicated on the n x n result (the same PDSYRK + DGSUM2D
    structure as the iteration itself).  The bound feeds
    :func:`repro.core.zolo.run_dynamic`'s in-graph Zolotarev
    coefficients; each group selects its own term through the bundle's
    ``coeff_select`` and the fused combine psum over "zolo" produces
    the next iterate.

    ``r`` is fixed by the mesh's "zolo" axis (it is a *static* group
    count, exactly like ``zolo_pd``'s r).  ``first_mode`` in {"auto",
    "cholqr2", "chol"} selects the peeled first iteration
    ("householder" additionally allowed on sep=1 meshes; under "auto"
    the extreme-regime branch substitutes shifted CholeskyQR2 on sep>1
    meshes — structured Householder QR is not row-distributable).
    Returns Q (or (Q, PolarInfo) with ``return_info=True``); the info
    carries the runtime iteration count, final residual, and final l.
    """
    r, nsep, has_sep, m, n, m_pad, x_spec = _mesh_layout(
        a, mesh, r, first_mode, qr_iters=1,
        first_iter_modes=("auto",), mode_knob="first_mode")
    dtype = a.dtype
    # accumulation-precision tolerance (see repro.core.zolo.zolo_pd):
    # a bf16 iterate still accumulates and factorizes in f32
    eps_f = eps or float(jnp.finfo(jnp.promote_types(dtype,
                                                     jnp.float32)).eps)
    alpha = _norms.sigma_max_upper(a) if alpha is None else jnp.asarray(alpha)
    x0 = a / alpha.astype(dtype)
    if m_pad != m:
        x0 = jnp.pad(x0, ((0, m_pad - m), (0, 0)))
    coeff_dtype = jnp.promote_types(dtype, jnp.float32)
    if combine_kernel is None:
        combine_kernel = _default_combine_kernel(dtype)
    if gram_kernel is None:
        gram_kernel = _default_gram_kernel(dtype)

    # check_rep=False: the rep checker cannot type the fori_loop carry of
    # the in-graph sigma_min estimate (the loop runs on the post-psum —
    # replicated — Gram, but the checker rejects the carry's widening
    # replication; jax suggests exactly this workaround).  Replication is
    # established by construction: every scalar derives from "sep"-psum
    # results and the iterate from the "zolo" combine psum.
    @functools.partial(shard_map, mesh=mesh, in_specs=(x_spec,),
                       out_specs=(x_spec, P(), P(), P(), P(), P()),
                       check_rep=False)
    def run(x):
        if x.shape != (m_pad // nsep, n):
            raise AssertionError(
                f"iterate not row-sharded over 'sep': per-device shape "
                f"{x.shape}, expected ({m_pad // nsep}, {n}) "
                f"(m_pad={m_pad}, sep={nsep})")
        xw = (jax.lax.axis_index("zolo") == 0).astype(coeff_dtype)
        ops = _group_ops(has_sep, xw, combine_kernel, gram_kernel)
        if l is None:
            # the paper's runtime kappa estimate, distributed: partial
            # Gram + psum("sep") through the collective bundle (zero
            # pad rows contribute nothing), inverse-power replicated
            l0 = _norms.sigma_min_lower(x, iters=est_iters, gram=ops.gram)
        else:
            l0 = jnp.asarray(l)
        l0 = jnp.clip(l0, 4 * eps_f, 1.0 - eps_f)
        l0 = l0.astype(jnp.result_type(l0, 0.0))
        out = _zolo.run_dynamic(x, l0, r, eps=eps_f, max_iters=max_iters,
                                first_mode=first_mode, ops=ops,
                                allow_householder=(nsep == 1))
        # the runtime bound rides out with the engine's state: it is the
        # in-graph analogue of the plan's kappa hint, and the resilience
        # verdict checks it against the envelope the plan was admitted
        # under (replicated: derived from "sep"-psum results)
        return out + (l0.astype(jnp.float32),)

    q, l_fin, k, res, conv, l_used = run(x0)
    if m_pad != m:
        q = q[:m]
    if return_info:
        return q, PolarInfo(iterations=k, residual=res, l_final=l_fin,
                            converged=conv, l_init=l_used)
    return q


# round-number prior for the psum cost charged per word until measured;
# benchmarks/comm_calibrate.py produces the calibrated replacement.  The
# REPRO_COMM_FLOPS_PER_WORD environment variable overrides the prior at
# resolution time (see grouped_iteration_flops) so a deployment can feed
# its own calibration in without editing SvdConfig at every call site.
DEFAULT_COMM_FLOPS_PER_WORD = 32.0


def grouped_iteration_flops(m: int, n: int, r: int, iters: int,
                            gram_shared: bool, sep: int = 1,
                            comm_flops_per_word=None) -> float:
    """Flops (summed over the r groups, per device within a group) of
    ``iters`` Cholesky-variant Zolotarev iterations on an m x n matrix.

    Per term: one n x n Cholesky (n^3/3; replicated on every device of
    the group — the CholeskyQR structure keeps it un-distributed) plus
    two triangular solves against the local row block (2 m n^2 / sep).
    The Gram product (2 m n^2 / sep local partial + one "sep"-axis psum
    of n^2 words) is paid once per *group* in the paper-faithful mode
    (each group owns one term and recomputes G) and once per *iteration*
    in the single-address-space gram-shared mode (sep must be 1 there:
    gram sharing is the one-address-space ablation).  Collectives are
    charged at ``comm_flops_per_word`` flop-equivalents per word: the
    n^2 "sep" Gram reduction and the (m n / sep) "zolo" combine — so the
    model prices the sep speed-up against its communication and the
    planner's grouped scoring (this total / r = the per-group critical
    path) stays honest for sep > 1 meshes.

    ``comm_flops_per_word=None`` resolves to the
    ``REPRO_COMM_FLOPS_PER_WORD`` environment variable when set (a
    deployment-wide calibration hook, read at every resolution so tests
    can monkeypatch the environment), else to the
    ``DEFAULT_COMM_FLOPS_PER_WORD`` prior (so cost models can pass a
    caller's possibly-absent calibration straight through);
    ``benchmarks/comm_calibrate.py`` measures the actual psum cost per
    word against the device's matmul flop rate — per compute dtype, bf16
    included — (committed as ``BENCH_comm.json``), and a calibrated
    value threads through planning via
    ``SvdConfig.extra["comm_flops_per_word"]``.
    """
    if comm_flops_per_word is None:
        env = os.environ.get("REPRO_COMM_FLOPS_PER_WORD")
        comm_flops_per_word = (float(env) if env
                               else DEFAULT_COMM_FLOPS_PER_WORD)
    if sep < 1:
        raise ValueError(f"sep degree must be >= 1, got {sep}")
    if gram_shared and sep != 1:
        raise ValueError("gram_shared is the single-address-space mode; "
                         "the sep axis does not apply (got sep="
                         f"{sep})")
    gram = 2.0 * m * n * n / sep
    per_term = n ** 3 / 3.0 + 2.0 * m * n * n / sep
    if gram_shared:
        per_iter = gram + r * per_term
    else:
        comm = comm_flops_per_word * (
            (float(n * n) if sep > 1 else 0.0)      # "sep" Gram psum
            + (m * n / sep if r > 1 else 0.0))      # "zolo" combine psum
        per_iter = r * (gram + per_term + comm)
    return float(iters * per_iter)
