"""Logical-axis sharding: the layer algorithm code targets.

Model / optimizer / data code annotates arrays with *logical* axis names
("batch", "embed", "experts", "opt_rows", ...).  How those names bind to
physical mesh axes is decided once, at launch, by a :class:`LogicalRules`
table (built by :func:`arch_rules`).  This keeps every call site
mesh-agnostic: the same ``hint(x, "experts", None, None)`` lowers to a
``with_sharding_constraint`` on a 512-chip production mesh and to a no-op
in a single-device unit test.

Two consumption modes:

* **Placement** — :func:`logical_sharding` / :func:`tree_shardings` turn
  logical axes into concrete :class:`~jax.sharding.NamedSharding`s for
  ``device_put`` / ``jax.jit`` in/out shardings (launcher + dry-run path).
* **Constraint** — :func:`hint` / :func:`hint_tree` inside traced code.
  They are identity functions unless an :func:`activation_hints` context
  (which carries the rules *and* their mesh) is active, so library code
  can sprinkle hints freely without coupling to any mesh.

Vocabulary note (Algorithm 3 mapping): the Zolo-PD process groups get
their own mesh axes ("zolo", "sep") built by
:func:`repro.dist.grouped.zolo_group_mesh`; model meshes use
("pod",) "data", "model".  Rules tables never mix the two.
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# A logical-axis annotation for one array dimension: a logical name, or
# None (replicated).  "REPLICATED" as a *whole-leaf* annotation marks a
# fully replicated array of any rank.
AxisName = Optional[str]
Axes = Union[None, str, Tuple[AxisName, ...]]

REPLICATED = "REPLICATED"


class LogicalRules:
    """Immutable logical-name -> mesh-axis rule table.

    ``rules`` maps each logical axis name to a physical mesh axis name, a
    tuple of mesh axis names (the dimension is sharded over their
    product, e.g. ``("pod", "data")``), or None (replicated).  Unknown
    logical names resolve to None, so partial tables are safe.

    The table may carry the mesh it was built against (``mesh=``); that
    is what lets :func:`hint` build shardings inside traced code.
    """

    __slots__ = ("_table", "mesh")

    def __init__(self, rules: Mapping[str, Any], mesh: Optional[Mesh] = None):
        table = {}
        for name, ax in dict(rules).items():
            if ax is not None and not isinstance(ax, (str, tuple)):
                raise TypeError(f"rule for {name!r} must be a mesh axis "
                                f"name, tuple, or None; got {ax!r}")
            table[name] = tuple(ax) if isinstance(ax, tuple) else ax
        self._table = table
        self.mesh = mesh

    def axis(self, name: Optional[str]):
        """Mesh axis (or axes tuple, or None) for one logical name."""
        if name is None:
            return None
        return self._table.get(name)

    def spec(self, axes: Axes, mesh: Optional[Mesh] = None) -> P:
        """Resolve a per-dimension logical-axes annotation to a
        PartitionSpec, dropping mesh axes the target mesh doesn't have."""
        mesh = mesh if mesh is not None else self.mesh
        present = set(mesh.axis_names) if mesh is not None else None

        def resolve(name):
            ax = self.axis(name)
            if ax is None:
                return None
            if isinstance(ax, tuple):
                if present is not None:
                    ax = tuple(a for a in ax if a in present)
                if not ax:
                    return None
                return ax[0] if len(ax) == 1 else ax
            if present is not None and ax not in present:
                return None
            return ax

        if axes is None or axes == REPLICATED:
            return P()
        if isinstance(axes, str):  # single logical name for a 1-D array
            return P(resolve(axes))
        return P(*(resolve(name) for name in axes))

    def sharding(self, axes: Axes, mesh: Optional[Mesh] = None
                 ) -> NamedSharding:
        mesh = mesh if mesh is not None else self.mesh
        if mesh is None:
            raise ValueError("LogicalRules has no mesh bound; pass mesh=")
        return NamedSharding(mesh, self.spec(axes, mesh))

    def items(self):
        return self._table.items()

    def __repr__(self):
        return (f"LogicalRules({self._table!r}, "
                f"mesh={None if self.mesh is None else dict(self.mesh.shape)})")


def logical_sharding(mesh: Mesh, rules: LogicalRules, axes: Axes
                     ) -> NamedSharding:
    """NamedSharding for one array annotated with logical ``axes``."""
    return rules.sharding(axes, mesh=mesh)


def _is_axes_leaf(x) -> bool:
    """Leaves of an *axes tree*: None, "REPLICATED"/a logical name, or a
    per-dimension tuple of names.  Structural tuples (tuples of dicts /
    tuples) are containers, not leaves."""
    return (x is None or isinstance(x, str)
            or (isinstance(x, tuple)
                and all(e is None or isinstance(e, str) for e in x)))


def tree_shardings(mesh: Mesh, rules: LogicalRules, axes_tree):
    """Map an axes tree (mirroring a param/state tree, with tuple-of-names
    leaves) to a matching tree of NamedShardings.

    ``None`` axes leaves stay ``None`` so the result zips cleanly against
    abstract trees that hold ``None`` at the same spots (e.g. nonparam-LN
    norms)."""

    def one(ax):
        if ax is None:
            return None
        return logical_sharding(mesh, rules, ax)

    return jax.tree.map(one, axes_tree, is_leaf=_is_axes_leaf)


# --- activation hints (constraint mode) ------------------------------------

# ContextVar rather than a module-global stack: concurrent traces (e.g.
# lowering two configs from a thread pool) must each see only their own
# rules.
_ACTIVE_RULES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dist_active_rules", default=())


def current_rules() -> Optional[LogicalRules]:
    """The innermost active :func:`activation_hints` rules, or None."""
    stack = _ACTIVE_RULES.get()
    return stack[-1] if stack else None


@contextlib.contextmanager
def activation_hints(rules: LogicalRules):
    """Enable :func:`hint` / :func:`hint_tree` under this block.

    The rules must carry a mesh (``arch_rules`` binds one).  Tracing a
    function inside this context bakes the constraints into the jaxpr;
    outside it, hints are exact no-ops — so hint-annotated library code
    costs nothing in single-device tests.
    """
    if rules.mesh is None:
        raise ValueError("activation_hints requires mesh-bound rules "
                         "(build them with arch_rules(cfg, mesh, shape))")
    token = _ACTIVE_RULES.set(_ACTIVE_RULES.get() + (rules,))
    try:
        yield rules
    finally:
        _ACTIVE_RULES.reset(token)


def hint(x, *logical_axes: AxisName):
    """Constrain ``x``'s sharding by per-dimension logical axis names.

    Identity (returns ``x`` itself) when no :func:`activation_hints`
    context is active; ``with_sharding_constraint`` against the active
    rules' mesh otherwise."""
    rules = current_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, rules.sharding(tuple(logical_axes)))


def hint_tree(tree, axes_tree):
    """Tree version of :func:`hint`.

    ``axes_tree`` mirrors ``tree`` with axes-leaves (tuples of logical
    names, "REPLICATED", or None) at array positions; extra trailing
    structure rules are resolved leaf-by-leaf.  Identity outside an
    :func:`activation_hints` context."""
    rules = current_rules()
    if rules is None:
        return tree

    def one(x, ax):
        if ax is None:
            return x
        return jax.lax.with_sharding_constraint(x, rules.sharding(ax))

    return jax.tree.map(one, tree, axes_tree)


# --- rules construction -----------------------------------------------------


def _batch_axes(mesh: Mesh, global_batch: Optional[int]):
    """Mesh axes the batch dimension shards over: ('pod','data') when both
    exist, else 'data' — degraded to fewer axes (or None) when the batch
    doesn't divide."""
    cand = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    while cand:
        size = math.prod(mesh.shape[a] for a in cand)
        if global_batch is None or global_batch % size == 0:
            return cand if len(cand) > 1 else cand[0]
        cand = cand[1:]
    return None


def arch_rules(cfg, mesh: Mesh, shape=None) -> LogicalRules:
    """Logical -> mesh rules for one (architecture, mesh, shape) cell.

    Policy (single table shared by params, activations, caches, data, and
    the optimizer — the names are the contract, this function is the only
    place that binds them):

    * "batch" / "cache_batch": DP over ("pod","data") when divisible.
    * tensor-parallel dims ("vocab", "qkv", "mlp", "state", "ssd_in",
      "cache_heads") and the expert axis: over "model".
    * "embed": FSDP over "data" when the model dim divides it — the
      train step re-pins bf16 casts + grads to this, which is what turns
      the gradient reduction into a reduce-scatter.
    * optimizer reshard ("opt_stack", "opt_rows"): stack over "model"
      (expert/layer-major), long dim over "data" — the Zolo-PD Gram then
      contracts over sharded rows with a single psum.
    """
    has_model = "model" in mesh.axis_names
    has_data = "data" in mesh.axis_names
    model = "model" if has_model else None
    data = "data" if has_data else None
    global_batch = getattr(shape, "global_batch", None)
    batch = _batch_axes(mesh, global_batch)

    d_model = getattr(cfg, "d_model", 0)
    embed = data if (data and d_model
                     and d_model % mesh.shape["data"] == 0) else None

    table = {
        # data / activations
        "batch": batch,
        "seq": None,
        "cache_batch": batch,
        "cache_heads": model,
        # parameters
        "vocab": model,
        "embed": embed,
        "layers": None,
        "qkv": model,
        "mlp": model,
        "state": model,
        "ssd_in": model,
        "experts": model if getattr(cfg, "num_experts", 0) else None,
        "expert_mlp": None,
        # optimizer (ZoloMuon factorization reshard)
        "opt_stack": model,
        "opt_rows": data,
    }
    return LogicalRules(table, mesh=mesh)
