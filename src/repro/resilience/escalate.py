"""Deterministic escalation: from a failed solve to the next-safer plan.

When a solve's runtime verdict (:mod:`repro.resilience.health`) comes
back unhealthy, there is a well-ordered set of things to try next, and
every one of them is already a planner capability — the ladder never
invents a solver, it re-plans through the existing LRU cache with a
config one notch more conservative:

1. **as planned** — the rung-0 config itself (its verdict is what
   starts the climb).
2. **kernel fallback** — the registry spec's ``fallback`` method (e.g.
   ``zolo_pallas -> zolo_static``): same math on the XLA engine, out of
   the kernel's f32-accumulation envelope.
3. **first-iteration factorization** — up the stability order
   ``chol -> cholqr2 -> householder`` (paper §3.1: the structured
   Householder QR is the paper-faithful stable term).
4. **static -> dynamic** — drop the trace-time schedule for a
   runtime-conditioning backend (``l0_policy="runtime"``): whatever
   mis-estimate of l0/kappa broke the schedule, the in-graph bound
   re-measures it.
5. **f32 -> f64 compute** — the last resort for precision-limited
   breakdowns.

Rungs are derived from registry capability flags (``fallback``,
``dynamic``) and the config — never from method names — so a new
backend slots into the ladder by declaring its flags.  A rung whose
config cannot plan in this environment (e.g. ``householder`` on a
sep>1 mesh) is recorded in the trail and skipped, not silently
dropped.  If no rung passes, :class:`~repro.resilience.errors.
SolveFailure` carries the full :class:`RungAttempt` trail out.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax.numpy as jnp

from repro.core import registry as _registry
from repro.core.zolo import ITER_MODES
from repro.resilience import health as _health
from repro.resilience.errors import SolveFailure
from repro.solver.config import SvdConfig


@dataclasses.dataclass(frozen=True)
class RungAttempt:
    """One rung of the ladder, as actually tried."""

    rung: int
    reason: str
    config: SvdConfig
    outcome: str  # "passed" | "failed" | "plan-error"
    error: Optional[str] = None
    verdict: Optional[_health.HealthVerdict] = None


# stability order of the first-iteration factorization (ITER_MODES is
# the engine's unordered choice set; this is the escalation order)
_QR_LADDER = ("chol", "cholqr2", "householder")
# what the engine actually runs when qr_mode is unset (the planner's
# static default / the dynamic drivers' mid-regime pick)
_QR_DEFAULT = "cholqr2"


def escalation_ladder(plan) -> List[Tuple[SvdConfig, str]]:
    """Ordered ``(config, reason)`` rungs for ``plan``, rung 0 first.

    Deterministic — same plan, same ladder — and derived from the
    rung-0 plan's resolved method spec, so ``method="auto"`` configs
    escalate from what auto actually picked.
    """
    if set(_QR_LADDER) != set(ITER_MODES):
        raise RuntimeError(
            f"escalation ladder order {_QR_LADDER} no longer covers the "
            f"engine's iteration modes {ITER_MODES}; update _QR_LADDER")
    cfg = plan.config
    rungs: List[Tuple[SvdConfig, str]] = [(cfg, "as planned")]
    spec = _registry.get_polar(plan.method)
    cur = cfg

    if spec.fallback is not None:
        # pin the resolved method first so the fallback replaces what
        # actually ran, not an "auto" re-resolution back to the kernel
        cur = cur.replace(method=spec.fallback)
        rungs.append((cur,
                      f"kernel fallback {spec.name} -> {spec.fallback}"))
        spec = _registry.get_polar(spec.fallback)

    qr_now = cur.qr_mode if cur.qr_mode is not None else _QR_DEFAULT
    start = _QR_LADDER.index(qr_now) if qr_now in _QR_LADDER \
        else len(_QR_LADDER) - 1
    for mode in _QR_LADDER[start + 1:]:
        cur = cur.replace(qr_mode=mode)
        rungs.append((cur, f"first-iteration factorization -> {mode}"))

    if not spec.dynamic and not spec.is_oracle:
        # re-measure the conditioning in-graph: whatever l0/kappa
        # mis-estimate broke the trace-time schedule does not carry
        # over.  qr_mode resets to the driver's runtime regime switch
        # (and householder would not plan on a sep>1 mesh anyway).
        cur = cur.replace(method="auto", mode="auto", l0=None, kappa=None,
                          l0_policy="runtime", qr_mode=None)
        rungs.append((cur, "static schedule -> runtime conditioning"))

    compute = cur.compute_dtype if cur.compute_dtype is not None \
        else plan.dtype
    if jnp.dtype(compute).itemsize < 8:
        cur = cur.replace(compute_dtype="float64")
        rungs.append((cur, "compute dtype -> float64"))

    deduped: List[Tuple[SvdConfig, str]] = []
    for rung in rungs:
        if not deduped or deduped[-1][0] != rung[0]:
            deduped.append(rung)
    return deduped


def solve_with_escalation(a, config: SvdConfig, *, mesh=None,
                          orth_tol: Optional[float] = None,
                          max_rungs: Optional[int] = None):
    """Verified SVD of one matrix, climbing the ladder until healthy.

    Plans flow through the normal plan cache (a retried rung re-uses its
    compiled executable), every attempt is judged by
    :func:`repro.resilience.health.judge_plan`, and the return is
    ``(u, s, vh, trail)`` from the first healthy rung.  Exhausting the
    ladder raises :class:`SolveFailure` carrying the full trail.

    Single-matrix by contract: batched callers (the serving layer) do
    their own per-entry triage so one poison matrix cannot drag its
    batch siblings up the ladder with it.
    """
    import repro.solver as _solver

    if a.ndim != 2:
        raise ValueError(
            f"solve_with_escalation takes one (m, n) matrix, got shape "
            f"{tuple(a.shape)}; batched callers triage entries "
            f"individually (see repro.serve)")
    shape = tuple(a.shape)
    plan0 = _solver.plan(config, shape, a.dtype, mesh=mesh)
    ladder = escalation_ladder(plan0)
    if max_rungs is not None:
        ladder = ladder[:max_rungs]
    trail: List[RungAttempt] = []
    for i, (cfg, reason) in enumerate(ladder):
        try:
            p = _solver.plan(cfg, shape, a.dtype, mesh=mesh)
        except (ValueError, TypeError) as e:
            trail.append(RungAttempt(rung=i, reason=reason, config=cfg,
                                     outcome="plan-error", error=str(e)))
            continue
        u, s, vh, health = p.svd_verified(a)
        verdict = _health.judge_plan(p, health, orth_tol=orth_tol)
        if verdict.ok:
            trail.append(RungAttempt(rung=i, reason=reason, config=cfg,
                                     outcome="passed", verdict=verdict))
            return u, s, vh, tuple(trail)
        trail.append(RungAttempt(rung=i, reason=reason, config=cfg,
                                 outcome="failed", verdict=verdict))
    raise SolveFailure(tuple(trail))
