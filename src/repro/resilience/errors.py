"""Typed failure surface of the resilience layer.

Every recovery path in :mod:`repro.resilience` and the fault-tolerant
serving layer ends in exactly one of two places: a healthy result, or
one of these exceptions.  Nothing times out silently, nothing hangs,
and nothing surfaces a bare ``RuntimeError`` a caller would have to
string-match — a client switches on the type:

* :class:`SolveFailure`     — the escalation ladder ran out of rungs;
  carries the full verdict trail (one :class:`~repro.resilience.escalate.
  RungAttempt` per rung tried) so the failure is diagnosable post hoc.
* :class:`DeadlineExceeded` — a request's deadline passed before its
  batch dispatched (or before its retry could run).
* :class:`Backpressure`     — the service shed the request at submit
  time because the queue depth was at its limit; the client should
  back off and resubmit.
* :class:`CircuitOpen`      — the request's bucket has failed
  repeatedly and its circuit breaker is cooling down; submits to other
  buckets are unaffected.
* :class:`FutureTimeout`    — ``SvdFuture.result(timeout=...)`` gave up
  waiting; the request itself is still in flight and the future can be
  waited on again.
"""

from __future__ import annotations

from typing import Tuple


class ResilienceError(Exception):
    """Base class for every typed failure the resilience layer raises."""


class SolveFailure(ResilienceError):
    """Every rung of the escalation ladder was tried and none produced a
    healthy solve.  ``trail`` holds the per-rung record — config, escalation
    reason, and the health verdict (or plan error) that failed it."""

    def __init__(self, trail: Tuple = (), message: str = ""):
        self.trail = tuple(trail)
        if not message:
            steps = "; ".join(
                f"[{t.rung}] {t.reason}: {t.outcome}"
                + (f" ({t.error})" if t.error else "")
                + (f" ({', '.join(t.verdict.reasons)})"
                   if getattr(t, "verdict", None) is not None
                   and t.verdict.reasons else "")
                for t in self.trail)
            message = (f"no escalation rung produced a healthy solve "
                       f"({len(self.trail)} tried: {steps})"
                       if self.trail else
                       "no escalation rung produced a healthy solve")
        super().__init__(message)


class DeadlineExceeded(ResilienceError):
    """The request's deadline passed before it could be (re)dispatched."""


class Backpressure(ResilienceError):
    """Submit-time load shed: the service queue is at its depth limit."""


class CircuitOpen(ResilienceError):
    """The request's bucket breaker is open after repeated plan failures;
    retry after the cooldown."""


class FutureTimeout(ResilienceError):
    """``SvdFuture.result(timeout=)`` expired; the request is still live
    and the future remains waitable."""
