"""Runtime breakdown detection and recovery.

Three layers, each usable alone:

* :mod:`repro.resilience.health` — in-graph :class:`SolveHealth` (one
  extra Gram reduction per solve) and the host-side
  :class:`HealthVerdict` that judges it.
* :mod:`repro.resilience.escalate` — the deterministic escalation
  ladder: re-plan one capability notch more conservative until a rung's
  verdict passes, else raise :class:`SolveFailure` with the full trail.
* :mod:`repro.resilience.faultinject` — deterministic fault injection
  (NaN / indefinite-Gram ops bundles, serving fault plans) so the
  recovery paths above are *tested* paths.

See ``src/repro/resilience/README.md`` for the failure-mode -> recovery
map and the serving-layer integration (:mod:`repro.serve`).
"""

from repro.resilience.errors import (Backpressure, CircuitOpen,
                                     DeadlineExceeded, FutureTimeout,
                                     ResilienceError, SolveFailure)
from repro.resilience.escalate import (RungAttempt, escalation_ladder,
                                       solve_with_escalation)
from repro.resilience.faultinject import ServiceFaults, faulty_ops
from repro.resilience.health import (HealthVerdict, SolveHealth,
                                     default_orth_tol, judge, judge_plan,
                                     solve_health)

__all__ = [
    "Backpressure",
    "CircuitOpen",
    "DeadlineExceeded",
    "FutureTimeout",
    "HealthVerdict",
    "ResilienceError",
    "RungAttempt",
    "ServiceFaults",
    "SolveFailure",
    "SolveHealth",
    "default_orth_tol",
    "escalation_ladder",
    "faulty_ops",
    "judge",
    "judge_plan",
    "solve_health",
    "solve_with_escalation",
]
