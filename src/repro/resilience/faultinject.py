"""Deterministic fault injection for resilience testing.

Two layers, matching the two recovery layers:

* :func:`faulty_ops` wraps any :class:`repro.core.zolo.ZoloOps` bundle
  so a chosen iteration's output goes NaN, or a chosen Gram goes
  indefinite (the ROADMAP-4a Pallas breakdown, reproduced on demand on
  any backend).  The wrapped bundle rides into a plan through
  ``SvdConfig.extra=(("ops", ops),)`` — the same injection point the
  Pallas kernels use — so the *production* escalation ladder is what
  recovers, not a test double.
* :class:`ServiceFaults` is the serving-layer fault plan a
  ``ServiceConfig`` carries: per-request input corruption (recoverable
  on retry, or permanent poison), dispatch-time exceptions, and clock
  skew.  All deterministic — a chaos test replays exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.zolo import DEFAULT_OPS, ZoloOps


def faulty_ops(base: Optional[ZoloOps] = None, *,
               nan_at_iter: Optional[int] = None,
               indefinite_at_iter: Optional[int] = None,
               indefinite_shift: float = 1.0e6) -> ZoloOps:
    """Wrap ``base`` so a chosen iteration misbehaves.

    ``nan_at_iter=k`` NaNs the k-th ``polar_update`` output — the one
    combine every driver calls exactly once per iteration, so k counts
    iterations in every mode.  ``indefinite_at_iter=k`` subtracts
    ``indefinite_shift * I`` from the k-th ``gram`` result, driving its
    Cholesky NaN exactly the way the f32 kernel envelope does.

    Iteration indices count *traced call sites*: exact iteration
    numbers for static (unrolled) schedules; for dynamic drivers index
    0 is the peeled first iteration and index 1 the while-loop body
    (i.e. every remaining iteration) — provided the first-iteration
    mode is pinned (``qr_mode``/``first_mode`` set): ``"auto"`` traces
    all three ``lax.switch`` branches, each its own call site.  Because
    each site fires at most once and a ladder retry traces fresh call
    sites, the injected fault is *transient*: the rung that retries the
    same config sees healthy ops — exactly the single-event upset model
    the escalation ladder is built for.  Each ``faulty_ops`` call
    returns a fresh bundle (closures compare by identity), so two
    injections never share a plan-cache entry.
    """
    base = DEFAULT_OPS if base is None else base
    calls = {"polar_update": 0, "gram": 0}

    def polar_update(x, t, a, mhat):
        k = calls["polar_update"]
        calls["polar_update"] += 1
        out = base.polar_update(x, t, a, mhat)
        if nan_at_iter is not None and k == nan_at_iter:
            out = out * jnp.asarray(float("nan"), out.dtype)
        return out

    def gram(x, c=0.0):
        k = calls["gram"]
        calls["gram"] += 1
        g = base.gram(x, c)
        if indefinite_at_iter is not None and k == indefinite_at_iter:
            n = g.shape[-1]
            g = g - jnp.asarray(indefinite_shift, g.dtype) * jnp.eye(
                n, dtype=g.dtype)
        return g

    return base._replace(polar_update=polar_update, gram=gram)


@dataclasses.dataclass(frozen=True)
class ServiceFaults:
    """Deterministic serving-layer fault plan (``ServiceConfig.faults``).

    * ``nan_request_seqs`` — submit sequence numbers whose batch slot is
      overwritten with NaNs at dispatch, while the request's retry rung
      is below ``nan_below_rung``.  With the default ``nan_below_rung=1``
      the rung-0 solve fails its health check but the first retry sees
      the clean input again — exercising ladder recovery end to end.  A
      value above the service's ``max_retries`` makes the request
      permanent poison and drives the quarantine path instead.
    * ``dispatch_error_batches`` — dispatch indices (0-based count of
      ``_dispatch`` calls) that raise ``RuntimeError(dispatch_error)``
      instead of launching, exercising batch-wide failure propagation.
    * ``clock_skew`` — seconds added to every service clock read;
      positive skew ages queued requests toward their deadlines.
    """

    nan_request_seqs: Tuple[int, ...] = ()
    nan_below_rung: int = 1
    dispatch_error_batches: Tuple[int, ...] = ()
    dispatch_error: str = "injected dispatch fault"
    clock_skew: float = 0.0
