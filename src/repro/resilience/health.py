"""In-graph solve health: the cheap runtime verdict every plan can emit.

The failure modes this repo has actually measured — the Pallas f32
indefinite-Gram NaN (ROADMAP 4a), a dynamic driver exiting its
``while_loop`` at ``max_iters`` with the residual rule unmet, a runtime
conditioning estimate beyond a kernel's precision envelope — all share
one property: the factors come back *plausible-looking*.  NaNs aside,
nothing downstream notices until accuracy silently degrades.

:func:`solve_health` closes that gap inside the compiled graph: one
extra Gram reduction (the ``UᵀU`` orthogonality residual — the paper's
OrthL metric, eq. 14) plus three scalar reductions that are free next
to the solve itself.  ``SvdPlan.svd_verified`` appends it to the solve
executable, so verification adds no extra host round trip and no
retrace.

The host-side half — :func:`judge` / :func:`judge_plan` — turns the
device scalars into a frozen :class:`HealthVerdict` with human-readable
reasons; the escalation ladder (:mod:`repro.resilience.escalate`) and
the serving triage loop key on ``verdict.ok`` and never inspect raw
floats themselves.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core import norms as _norms
from repro.core import registry as _registry


class SolveHealth(NamedTuple):
    """Device-side health scalars, computed inside the solve graph.

    A NamedTuple of scalars so it vmaps: ``svd_batched_verified`` returns
    one with a leading batch axis on every leaf, and the serving triage
    loop indexes per-entry health out of it.
    """

    finite: jnp.ndarray     # bool: all of u, s, vh finite
    orth: jnp.ndarray       # f32: ||UᵀU - I||_F / n  (paper OrthL)
    converged: jnp.ndarray  # bool: the driver's stopping rule was met
    kappa_est: jnp.ndarray  # f32: 1 / l_init — the conditioning the
                            # solve actually ran under; NaN when unknown


def solve_health(u, s, vh, info=None) -> SolveHealth:
    """In-graph health of an SVD result (one extra Gram reduction).

    The orthogonality residual is masked to the columns whose singular
    values clear a rank-revealing cutoff (``max(m, n) * eps * s_max``):
    null-space columns of a rank-deficient input — every zero-padded
    serving slot is one — are an arbitrary completion the algorithm
    never promised to orthonormalize, and the columns that carry the
    answer are exactly the ones the check must hold to eps.
    """
    finite = (jnp.all(jnp.isfinite(u), axis=(-2, -1))
              & jnp.all(jnp.isfinite(s), axis=-1)
              & jnp.all(jnp.isfinite(vh), axis=(-2, -1)))
    n = u.shape[-1]
    g = jnp.einsum("...mk,...mn->...kn", u, u,
                   preferred_element_type=jnp.promote_types(u.dtype,
                                                            jnp.float32))
    cutoff = (max(u.shape[-2], n) * jnp.finfo(u.dtype).eps
              * jnp.max(s, axis=-1, keepdims=True))
    valid = s > cutoff          # NaN s -> all-False; `finite` still fails
    mask = valid[..., :, None] & valid[..., None, :]
    n_valid = jnp.maximum(jnp.sum(valid, axis=-1), 1)
    resid = jnp.where(mask, g - jnp.eye(n, dtype=g.dtype), 0.0)
    orth = (_norms.frobenius(resid) / n_valid).astype(jnp.float32)
    if info is not None:
        converged = jnp.asarray(info.converged)
        kappa_est = (1.0 / jnp.asarray(info.l_init, jnp.float32)) \
            .astype(jnp.float32)
    else:
        converged = jnp.asarray(True)
        kappa_est = jnp.asarray(float("nan"), jnp.float32)
    return SolveHealth(finite=finite, orth=orth, converged=converged,
                       kappa_est=kappa_est)


def default_orth_tol(dtype) -> float:
    """Orthogonality acceptance threshold for a compute dtype.

    A healthy Zolo/QDWH solve lands at a small multiple of eps (paper
    Tables 5/10: OrthL within ~10 eps); a broken one is off by many
    orders.  1e4 * eps splits the two regimes with wide margin on both
    sides (f64 ~2e-12, f32 ~1e-3).  Sub-f32 dtypes need a far tighter
    multiplier: 1e4 * eps(bf16) = 78 would accept anything, while a
    healthy bf16 solve (f32 accumulation, factors rounded to bf16)
    measures orth ~ 1-2 eps(bf16) and a broken one >= O(1), so 8 * eps
    (~0.06 for bf16) splits those regimes."""
    d = jnp.dtype(dtype)
    mult = 1.0e4 if d.itemsize >= 4 else 8.0
    return mult * float(jnp.finfo(d).eps)


@dataclasses.dataclass(frozen=True)
class HealthVerdict:
    """Host-side judgment of one solve: ``ok`` plus why not."""

    ok: bool
    reasons: Tuple[str, ...]
    finite: bool
    orth: float
    converged: bool
    kappa_est: float
    orth_tol: float
    kappa_max: Optional[float] = None

    def __str__(self):
        if self.ok:
            return f"healthy (orth={self.orth:.2e})"
        return "unhealthy: " + "; ".join(self.reasons)


def judge(health: SolveHealth, *, orth_tol: float,
          kappa_max: Optional[float] = None) -> HealthVerdict:
    """Turn device health scalars into a frozen verdict (host side).

    ``kappa_max`` folds a backend's precision envelope into the runtime
    verdict: a dynamic plan has no conditioning hint at plan time, so
    the plan-time envelope check cannot fire — but the in-graph estimate
    (``kappa_est = 1/l_init``) exists at execution time, and exceeding
    the envelope there is a health failure even if the factors happen
    to look finite.  A NaN ``kappa_est`` (driver with no bound) passes.
    """
    finite = bool(health.finite)
    orth = float(health.orth)
    converged = bool(health.converged)
    kappa_est = float(health.kappa_est)
    reasons = []
    if not finite:
        reasons.append("non-finite factors")
    if not (orth <= orth_tol):  # NaN-propagating: NaN orth also fails
        reasons.append(f"orthogonality {orth:.3e} > tol {orth_tol:.3e}")
    if not converged:
        reasons.append("stopping rule unmet at the iteration cap")
    if kappa_max is not None and not math.isnan(kappa_est) \
            and kappa_est > kappa_max:
        reasons.append(f"runtime kappa estimate {kappa_est:.3g} beyond "
                       f"the backend envelope {kappa_max:.3g}")
    return HealthVerdict(ok=not reasons, reasons=tuple(reasons),
                         finite=finite, orth=orth, converged=converged,
                         kappa_est=kappa_est, orth_tol=orth_tol,
                         kappa_max=kappa_max)


def judge_plan(plan, health: SolveHealth, *,
               orth_tol: Optional[float] = None) -> HealthVerdict:
    """Judge one solve against its plan's own contract.

    The orthogonality tolerance comes from the precision the solve
    actually computed in (``compute_dtype`` when set, the plan dtype
    otherwise), and the conditioning envelope from the backend's
    registry spec resolved per compute dtype
    (:func:`repro.core.registry.envelope_kappa_max`: the
    ``kappa_envelope`` table entry for sub-f32 inputs, ``kappa_max_f32``
    for f32, nothing for f64) — the registry drives the check, never the
    backend's name.
    """
    compute = plan.config.compute_dtype
    dtype = jnp.dtype(compute) if compute is not None \
        else jnp.dtype(plan.dtype)
    if orth_tol is None:
        orth_tol = default_orth_tol(dtype)
    spec = _registry.get_polar(plan.method)
    kappa_max = _registry.envelope_kappa_max(spec, dtype)
    return judge(health, orth_tol=orth_tol, kappa_max=kappa_max)
