"""ZoloMuon: Muon-style orthogonalized momentum with Zolo-PD msign.

Muon replaces the elementwise Adam update for 2-D weights with the
orthogonal (polar) factor of the momentum matrix:

    M_t = beta M_{t-1} + G_t
    W  -= lr * 0.2 sqrt(max(m, n)) * polar_factor(M_t)

Standard Muon approximates the polar factor with 5 Newton-Schulz quintic
steps.  Here the orthogonalization is *the paper's algorithm*: Zolo-PD
with a static trace-time coefficient schedule (r=2, shifted-CholeskyQR2
first iteration, shared-Gram Cholesky after) — higher order, a tight
orthogonality guarantee, and r-term inner parallelism that maps onto the
mesh exactly like the paper's process groups.  ``method`` selects
{"zolo", "qdwh", "ns5"} so the paper's baseline comparisons also run
inside the training loop.

The factorization runs through one ``repro.solver`` SvdPlan per
parameter *kind* (shape, dtype, config): the Zolotarev schedule is built
once at plan time and the compiled executable is cached, so optimizer
steps after the first perform zero retraces.

Momentum matrices are near-isotropic in practice; the schedule assumes
sigma_min/sigma_max >= l0 (default 1e-3) after sigma_max-normalization.
Smaller singular values still converge monotonically (the composed
Zolotarev map is monotone on [0, 1]) — same argument as the paper's
fixed-small-r policy.

Muon applies to leaves with trailing 2-D blocks of min dim >= 64 that are
not embeddings / vocab projections (path-based rule); everything else
(norms, biases, convs, embed, lm_head) gets AdamW — the Muon reference
setup.  Stacked leading axes (layers, experts) are vmapped: one batched
Zolo-PD per parameter *kind* per step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import hint


class MuonConfig(NamedTuple):
    lr: float = 0.02
    beta: float = 0.95
    weight_decay: float = 0.0
    method: str = "zolo"  # zolo | qdwh | ns5
    r: int = 2
    l0: float = 1e-3
    max_iters: int = 4
    # dtype the momentum moves through the factorization reshard in;
    # bf16 halves the optimizer's collective bytes (the factorization
    # itself upcasts per-shard, so only the momentum rounding is bf16)
    polar_dtype: str = "float32"
    # AdamW for non-matrix leaves
    adam_lr: float = 3e-4
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    min_matrix_dim: int = 64


_NS5_COEFFS = (3.4445, -4.7750, 2.0315)


def _ns5(x, steps: int = 5):
    """Standard Muon Newton-Schulz quintic iteration (baseline)."""
    a, b, c = _NS5_COEFFS
    x = x / (jnp.linalg.norm(x, axis=(-2, -1), keepdims=True) + 1e-7)
    transpose = x.shape[-2] > x.shape[-1]
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    for _ in range(steps):
        g = jnp.einsum("...mk,...nk->...mn", x, x)
        bx = b * x + c * jnp.einsum("...mk,...kn->...mn", g, x)
        x = a * x + jnp.einsum("...mk,...kn->...mn", g, bx)
    if transpose:
        x = jnp.swapaxes(x, -1, -2)
    return x


@functools.lru_cache(maxsize=None)
def _polar_plan(method: str, rows: int, cols: int, r: int, l0: float,
                max_iters: int, polar_dtype: str):
    """One cached SvdPlan per parameter *kind* (shape, dtype, config).

    ``scale="power"`` is the sharp 1.05x power-iteration normalization
    that keeps the spectrum inside [l0, 1] so the static schedule's
    iteration count is honest; ``compute_dtype="float32"`` factorizes in
    f32 per shard and casts back to ``polar_dtype``.  The lru_cache pins
    the plan (and its compiled executables) per kind regardless of
    pressure on the solver's global LRU, so every optimizer step after
    the first reuses one executable — no per-step schedule rebuilds or
    retraces.
    """
    import repro.solver as _solver

    if method == "zolo":
        cfg = _solver.SvdConfig(method="zolo_static", r=r, l0=l0,
                                max_iters=max_iters, qr_mode="cholqr2",
                                qr_iters=1, scale="power",
                                compute_dtype="float32")
    else:  # qdwh
        cfg = _solver.SvdConfig(method="qdwh_static", l0=l0,
                                max_iters=max_iters + 2, scale="power",
                                compute_dtype="float32")
    return _solver.plan(cfg, (rows, cols), jnp.dtype(polar_dtype))


def orthogonalize(m, method: str = "zolo", r: int = 2, l0: float = 1e-3,
                  max_iters: int = 4, polar_dtype: str = "float32"):
    """Batched msign/polar factor of m (..., rows, cols)."""
    if method == "ns5":
        return _ns5(m)

    lead = m.shape[:-2]
    rows, cols = m.shape[-2:]
    out_dtype = m.dtype
    m2 = m.reshape((-1, rows, cols)).astype(jnp.dtype(polar_dtype))
    # §Perf sharding: stack over "model" (expert/layer-major, matching the
    # experts' native layout), long dim over "data".  The Gram contracts
    # over the sharded rows (one psum of (n, n)), the right-side TRSM
    # solves rows independently, and only the small Cholesky replicates —
    # no full-matrix gathers anywhere in the optimizer chain.
    if rows >= cols:
        m2 = hint(m2, "opt_stack", "opt_rows", None)
    else:
        m2 = hint(m2, "opt_stack", None, "opt_rows")

    plan = _polar_plan(method, rows, cols, r, l0, max_iters, polar_dtype)
    q, _, _ = plan.polar_batched(m2, want_h=False)
    if rows >= cols:
        q = hint(q, "opt_stack", "opt_rows", None)
    else:
        q = hint(q, "opt_stack", None, "opt_rows")
    return q.reshape(lead + (rows, cols)).astype(out_dtype)


def _path_keys(path):
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(k.key)
        elif hasattr(k, "name"):
            out.append(k.name)
        elif hasattr(k, "idx"):
            out.append(k.idx)
    return out


def muon_labels(params, min_dim: int = 64):
    """True -> Muon, False -> AdamW; mirrors params exactly."""

    def f(path, leaf):
        keys = _path_keys(path)
        if "embed" in keys or "lm_head" in keys:
            return False
        return leaf.ndim >= 2 and min(leaf.shape[-2:]) >= min_dim

    return jax.tree_util.tree_map_with_path(f, params)


@dataclasses.dataclass
class ZoloMuon:
    """Pytree optimizer: Muon (Zolo-PD) for matrices, AdamW for the rest."""

    cfg: MuonConfig
    labels: Any  # bool pytree matching params (muon_labels)

    def init(self, params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        flags = jax.tree.leaves(self.labels)
        mu = jax.tree.map(zeros32, params)
        # second moment only for Adam leaves (Muon leaves keep a scalar
        # placeholder to avoid doubling optimizer memory)
        nu_leaves = [
            zeros32(p) if not is_muon else jnp.zeros((), jnp.float32)
            for p, is_muon in zip(jax.tree.leaves(params), flags)]
        nu = jax.tree.unflatten(jax.tree.structure(params), nu_leaves)
        return {"mu": mu, "nu": nu, "count": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr_scale=1.0):
        c = self.cfg
        count = state["count"] + 1
        bc1 = 1.0 - c.adam_b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - c.adam_b2 ** count.astype(jnp.float32)

        p_leaves, tdef = jax.tree.flatten(params)
        g_leaves = jax.tree.leaves(grads)
        mu_leaves = jax.tree.leaves(state["mu"])
        nu_leaves = jax.tree.leaves(state["nu"])
        flags = jax.tree.leaves(self.labels)
        if not (len(p_leaves) == len(g_leaves) == len(flags)):
            raise ValueError(
                f"params/grads/labels trees disagree: "
                f"{len(p_leaves)} params, {len(g_leaves)} grads, "
                f"{len(flags)} labels — was the optimizer built for a "
                f"different model structure?")

        new_p, new_mu, new_nu = [], [], []
        for is_muon, p, g, mu, nu in zip(flags, p_leaves, g_leaves,
                                         mu_leaves, nu_leaves):
            g32 = g.astype(jnp.float32)
            if is_muon:
                mu_n = c.beta * mu + g32
                o = orthogonalize(mu_n, c.method, c.r, c.l0, c.max_iters,
                                  polar_dtype=c.polar_dtype)
                rows, cols = p.shape[-2:]
                scale = 0.2 * (max(rows, cols) ** 0.5)
                step = (c.lr * lr_scale) * scale * o
                if c.weight_decay:
                    step = step + (c.lr * lr_scale) * c.weight_decay \
                        * p.astype(jnp.float32)
                nu_n = nu
            else:
                mu_n = c.adam_b1 * mu + (1 - c.adam_b1) * g32
                nu_n = c.adam_b2 * nu + (1 - c.adam_b2) * g32 * g32
                step = (c.adam_lr * lr_scale) * (mu_n / bc1) / (
                    jnp.sqrt(nu_n / bc2) + c.adam_eps)
            new_p.append((p.astype(jnp.float32) - step).astype(p.dtype))
            new_mu.append(mu_n)
            new_nu.append(nu_n)

        return (jax.tree.unflatten(tdef, new_p),
                {"mu": jax.tree.unflatten(tdef, new_mu),
                 "nu": jax.tree.unflatten(tdef, new_nu),
                 "count": count})
