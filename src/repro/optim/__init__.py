"""Optimizers: ZoloMuon (the paper's PD inside the train step) + AdamW."""

from repro.optim.compression import (
    compress_decompress,
    compressed_psum,
    init_compression_state,
    lowrank_factor,
    lowrank_truncate,
)
from repro.optim.muon import MuonConfig, ZoloMuon, muon_labels, orthogonalize
from repro.optim.schedule import warmup_cosine
