"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000,
                  floor: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return warm * (floor + (1.0 - floor) * cos)
