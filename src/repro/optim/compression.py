"""Low-rank gradient compression (PowerSGD-style) with error feedback.

The rank-k factorization's orthonormalization step is the paper's
machinery again: shifted CholeskyQR2 (structured-QR adaptation, DESIGN.md
§3) — a Gram + Cholesky + TRSM, matmul-shaped for the MXU.

Two entry points:

* :func:`compress_decompress` — in-graph transform G -> P Q^T with error
  feedback carried in the optimizer state; use under plain pjit where XLA
  owns the gradient all-reduce (communication saving then comes from
  reducing (P, Q) instead of G — see the shard_map variant).
* :func:`compressed_psum` — explicit shard_map building block: psum the
  (P, Q) factors over the data axis instead of the full gradient,
  cutting per-step gradient traffic to k(m+n)/(m n) of dense.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.structured_qr import cholesky_qr2 as _cholqr2


def lowrank_truncate(g, rank: int, *, strategy: str = "auto",
                     kappa=None, tol: float = 1e-6):
    """Best-rank-``rank`` factors (p, q) with G ~= P Q^T, through the
    partial-spectrum planner.

    Unlike the PowerSGD iteration below — one warm-started subspace
    step per optimizer tick, approximation quality amortized over
    steps — this is the *one-shot* truncation (checkpoint compression,
    compression-state initialization, accuracy flooring): it plans a
    :class:`repro.spectral.TopKConfig` at G's shape and takes the true
    leading-``rank`` triplets, so the result is the Eckart-Young
    optimum to the configured ``tol``.  ``strategy``/``kappa`` pass
    through to :func:`repro.spectral.plan_topk` (auto: cost model picks
    sketch vs dense).  Plans are cached per (shape, dtype, rank), so
    sweeping a parameter tree costs one compile per distinct shape.
    """
    from repro.spectral import TopKConfig, plan_topk

    plan = plan_topk(
        TopKConfig(k=int(rank), strategy=strategy, tol=tol,
                   kappa=None if kappa is None else float(kappa)),
        g.shape[-2:], g.dtype)
    u, s, vh = plan.topk(g) if g.ndim == 2 else plan.topk_batched(g)
    return u * s[..., None, :], jnp.swapaxes(vh, -1, -2)


def lowrank_factor(g, q_prev, rank: int):
    """One subspace-iteration step: G ~= P Q^T, P orthonormal (m, k)."""
    p = jnp.einsum("...mn,...nk->...mk", g, q_prev)
    p = _cholqr2(p)
    q = jnp.einsum("...mn,...mk->...nk", g, p)
    return p, q


def compress_decompress(g, err, q_prev, rank: int):
    """Error-feedback low-rank pass.  Returns (g_hat, new_err, q_new)."""
    g_fb = g + err
    p, q = lowrank_factor(g_fb, q_prev, rank)
    g_hat = jnp.einsum("...mk,...nk->...mn", p, q)
    return g_hat, g_fb - g_hat, q


def init_compression_state(param, rank: int, key):
    n = param.shape[-1]
    q = jax.random.normal(key, param.shape[:-2] + (n, rank), jnp.float32)
    return {"err": jnp.zeros(param.shape, jnp.float32), "q": q}


def compressed_psum(g, err, q_prev, rank: int, axis_name: str):
    """shard_map building block: all-reduce (P, Q) rather than G.

    Caller runs inside shard_map with ``g`` the *local* gradient shard
    (same shape on every member of ``axis_name``).  Traffic per matrix
    drops from m*n to k*(m+n)."""
    g_fb = g + err
    p = jnp.einsum("...mn,...nk->...mk", g_fb, q_prev)
    p = jax.lax.psum(p, axis_name)
    p = _cholqr2(p)
    q = jnp.einsum("...mn,...mk->...nk", g_fb, p)
    q = jax.lax.psum(q, axis_name)
    g_hat = jnp.einsum("...mk,...nk->...mn", p, q) / jax.lax.psum(
        jnp.ones(()), axis_name)
    return g_hat, g_fb - g_hat, q
