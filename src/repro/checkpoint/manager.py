"""Sharded checkpoint manager: atomic, resharding-safe, async-capable.

Fault-tolerance contract (DESIGN.md §4):

* **Atomicity** — writes land in ``step_N.tmp`` and are renamed only after
  the manifest (with per-leaf SHA-256) is fsynced; a crash mid-save never
  corrupts the latest checkpoint.
* **Elasticity** — leaves are saved as full (host-gathered) arrays plus
  the pytree structure; restore places them under *any* target sharding /
  mesh shape, so a job can come back on a different topology
  (tested across device counts in tests/test_checkpoint.py).
* **Retention** — keep_k GC, never deleting the newest complete step.
* **Async** — a single background thread serializes device-to-host copies
  so the train loop only blocks on the previous save.

For multi-pod scale the host-gather would be replaced by per-shard writes
keyed by shard index (same manifest format, ``shards`` field reserved).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_k: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep_k = keep_k
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # --- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, block: bool = False):
        names, leaves, _ = _tree_paths(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, names, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, names, host)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, names, host):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(zip(names, host)):
            fn = f"{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append({
                "name": name, "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_k] if self.keep_k else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                verify: bool = True):
        """Restore into the structure/shardings of ``target``.

        ``target`` leaves may be arrays (their .sharding is reused) or
        ShapeDtypeStructs with .sharding — either way the load reshards.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        names, leaves, treedef = _tree_paths(target)
        by_name = {e["name"]: e for e in manifest["leaves"]}
        out = []
        for name, like in zip(names, leaves):
            entry = by_name[name]
            arr = np.load(os.path.join(path, entry["file"]))
            if verify:
                h = hashlib.sha256(arr.tobytes()).hexdigest()
                if h != entry["sha256"]:
                    raise IOError(f"checksum mismatch for {name}")
            sharding = getattr(like, "sharding", None)
            if sharding is not None:
                out.append(jax.device_put(arr, sharding))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
