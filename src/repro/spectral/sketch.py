"""Randomized sketch frontend for top-k SVD (the PyParSVD direction).

The observation behind arXiv:2108.08845: when only k singular triplets
are wanted, the O(m n min(m,n)) full factorization is waste — sketch A
down to an O(k)-wide panel, run the *existing* solver on the panel, and
lift the left factor back.  Concretely (canonical tall A, m >= n):

    1.  range finder:  Y = (A A^T)^q A Omega with Omega an n x l test
        matrix (l = k + oversample), orthonormalized between every
        product by shifted CholeskyQR2
        (:func:`repro.core.structured_qr.cholesky_qr2`) so the power
        iterations never lose the small directions to roundoff;
    2.  project:       B = Q^T A   (l x n — an O(k)-width problem);
    3.  solve:         B = U_B diag(s) V^H through a cached
        :class:`repro.solver.SvdPlan` — the sketch frontend reuses the
        whole plan/execute machinery, backends and all;
    4.  lift:          U = Q U_B, keep the leading k triplets.

Test matrices: ``kind="gauss"`` (dense Gaussian, 2 m n l flops per
pass) or ``kind="srht"`` (subsampled randomized Hadamard transform:
random column signs, fast Walsh-Hadamard over the row axis, subsample —
O(m n log n) for the first pass; power passes are Gaussian-shaped
regardless since they reuse the orthonormalized iterate).

Accuracy is governed by the decay between sigma_k and sigma_{l+1}: the
standard bounds give relative value error ~ (sigma_{l+1}/sigma_k)^(4q+2)
after q power iterations.  :func:`needed_power_iters` inverts that model
under the geometric-spectrum assumption the rest of this repo
benchmarks with (sigma_i = kappa^(-(i-1)/(n-1))), which is how
``strategy="auto"`` in :mod:`repro.spectral.topk` decides whether the
sketch can hit the configured tolerance at all — a flat spectrum prices
the sketch out and the planner falls back to dense.

The a posteriori check is :func:`topk_residual`: one extra O(m n k)
pass measuring max_i ||A v_i - s_i u_i|| / sigma_1 — the escalation
trigger for adaptive solves (:meth:`repro.spectral.topk.TopKPlan.
topk_adaptive`).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.structured_qr import cholesky_qr2

SKETCH_KINDS = ("gauss", "srht")


def gaussian_sketch(a, l: int, key):
    """Y = A Omega with Omega an n x l standard Gaussian test matrix."""
    n = a.shape[-1]
    omega = jax.random.normal(key, (n, l), dtype=a.dtype)
    return jnp.einsum("...mn,nl->...ml", a, omega)


def _fwht(x):
    """Fast Walsh-Hadamard transform along the last axis (power-of-2
    length), normalized by 1/sqrt(len): log2(n) reshape-butterfly
    passes, each O(size)."""
    n = x.shape[-1]
    h = 1
    while h < n:
        x = x.reshape(x.shape[:-1] + (n // (2 * h), 2, h))
        a, b = x[..., 0, :], x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2).reshape(
            x.shape[:-3] + (n,))
        h *= 2
    return x / jnp.sqrt(jnp.asarray(n, x.dtype))


def srht_sketch(a, l: int, key):
    """Y = A D H S: random column signs, Walsh-Hadamard mix over the
    column axis (zero-padded to a power of 2), subsample l columns.

    The Hadamard mix spreads every right singular direction across all
    columns, so the uniform subsample is a with-high-probability range
    sketch like the Gaussian one at O(m n log n) cost for the first
    pass.  Deterministic per ``key``.
    """
    n = a.shape[-1]
    n_pad = 1 << max(1, (n - 1).bit_length())
    k_sign, k_pick = jax.random.split(key)
    signs = jax.random.rademacher(k_sign, (n,), dtype=a.dtype)
    x = a * signs
    if n_pad != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, n_pad - n)]
        x = jnp.pad(x, pad)
    x = _fwht(x) * jnp.sqrt(jnp.asarray(n_pad / l, x.dtype))
    cols = jax.random.choice(k_pick, n_pad, (l,), replace=False)
    return jnp.take(x, cols, axis=-1)


def randomized_range(a, l: int, q_iters: int, key, kind: str = "gauss"):
    """Orthonormal Q (m x l) approximately spanning the leading left
    singular subspace of ``a`` after ``q_iters`` power iterations.

    Every half-pass re-orthonormalizes through shifted CholeskyQR2, so
    ill-conditioned spectra (kappa ~ 1e10) neither underflow the small
    directions nor break the Cholesky (the ridge keeps rank-deficient
    iterates factorizable — the k >= rank case).
    """
    if kind not in SKETCH_KINDS:
        raise ValueError(f"sketch kind {kind!r} not in {SKETCH_KINDS}")
    sketch = srht_sketch if kind == "srht" else gaussian_sketch
    y = sketch(a, l, key)
    q = cholesky_qr2(y)
    acc = jnp.promote_types(a.dtype, jnp.float32)
    for _ in range(int(q_iters)):
        z = cholesky_qr2(jnp.einsum("...mn,...ml->...nl", a, q,
                                    preferred_element_type=acc)
                         .astype(a.dtype))
        q = cholesky_qr2(jnp.einsum("...mn,...nl->...ml", a, z,
                                    preferred_element_type=acc)
                         .astype(a.dtype))
    return q


def sketch_topk(a, *, k: int, l: int, q_iters: int, key,
                small_svd, kind: str = "gauss"):
    """Leading-k SVD of canonical-tall ``a`` through the sketch.

    ``small_svd`` solves the (l, n) projected panel — the uncompiled
    impl of a cached :class:`repro.solver.SvdPlan`, so the whole sketch
    compiles into ONE executable per top-k plan.  Returns
    (u (m, k), s (k,), vh (k, n)).
    """
    q = randomized_range(a, l, q_iters, key, kind=kind)
    b = jnp.einsum("...ml,...mn->...ln", q, a)
    u_b, s, vh = small_svd(b)
    u = jnp.einsum("...ml,...lk->...mk", q, u_b)
    return u[..., :, :k], s[..., :k], vh[..., :k, :]


def needed_power_iters(nmin: int, k: int, l: int,
                       kappa: float, tol: float,
                       margin: float = 1e-2) -> Optional[int]:
    """Power iterations needed for relative value error ``tol`` under
    the geometric-spectrum model, or None when no finite count works.

    Model: sigma_i = kappa^(-(i-1)/(nmin-1)), value error after q
    iterations ~ (sigma_{l+1}/sigma_k)^(4q+2); ``margin`` is the safety
    factor absorbing the model's constants.  l >= nmin is the
    exhaustive sketch (exact, 0 iterations); kappa <= 1 (no decay) can
    never converge by decay alone.
    """
    if l >= nmin:
        return 0
    kappa = float(kappa)
    if kappa <= 1.0:
        return None
    # log10 of the per-index decay ratio sigma_{l+1} / sigma_k < 1
    log_rho = -(l + 1 - k) * math.log10(kappa) / max(nmin - 1, 1)
    need = math.log10(float(tol) * margin) / log_rho  # 4q + 2 >= need
    return max(0, math.ceil((need - 2.0) / 4.0))


def sketch_flops(m: int, n: int, k: int, l: int, q_iters: int,
                 small_flops: float = 0.0) -> float:
    """Flop model for one sketch solve of a canonical (m, n) problem:
    first pass + 2 matmuls per power iteration + the CholeskyQR2
    orthonormalizations + projection + lift, plus the caller-supplied
    price of the (l, n) panel solve (from the solver's own cost model —
    see :func:`repro.solver.flops_estimate`)."""
    pass_ = 2.0 * m * n * l
    orth = 2.0 * (2.0 * m * l * l + l ** 3 / 3.0)
    per_iter = 2.0 * pass_ + 2.0 * orth
    return (pass_ + orth + q_iters * per_iter        # range finder
            + pass_                                  # B = Q^T A
            + float(small_flops)                     # SVD of B
            + 2.0 * m * l * k)                       # lift U = Q U_B


def topk_residual(a, u, s, vh):
    """A posteriori residual: max_i ||A v_i - s_i u_i||_2 / sigma_max.

    For an exact leading-k triplet set this is ~eps; a sketch that
    missed part of the leading subspace shows up here at the size of
    what it missed.  One O(m n k) pass — the escalation trigger for
    adaptive solves.  sigma_max is estimated as max(s_1, a power-
    iteration bound) so the scale is honest even if s itself is off.
    """
    from repro.core import norms as _norms

    av = jnp.einsum("...mn,...kn->...mk", a, vh)
    res = jnp.linalg.norm(av - u * s[..., None, :], axis=-2)
    smax = jnp.maximum(s[..., 0],
                       _norms.sigma_max_power(a, iters=4).astype(s.dtype))
    return jnp.max(res, axis=-1) / jnp.maximum(
        smax, jnp.finfo(s.dtype).tiny)
