"""repro.spectral: partial-spectrum workloads over the plan/execute stack.

Top-k / windowed SVD as a first-class citizen: a frozen
:class:`TopKConfig` resolves through :func:`plan_topk` into a cached
:class:`TopKPlan` whose strategies — randomized sketch
(:mod:`repro.spectral.sketch`), spectral divide-and-conquer
(:mod:`repro.spectral.dnc`), or dense-and-slice — all execute through
the existing :mod:`repro.solver` registry backends.  See
:mod:`repro.spectral.topk` for the strategy-selection contract.
"""

from repro.spectral.dnc import (
    bisect_shift,
    count_above,
    dnc_flops,
    dnc_topk,
)
from repro.spectral.sketch import (
    SKETCH_KINDS,
    gaussian_sketch,
    needed_power_iters,
    randomized_range,
    sketch_flops,
    sketch_topk,
    srht_sketch,
    topk_residual,
)
from repro.spectral.topk import (
    STRATEGIES,
    TopKConfig,
    TopKPlan,
    clear_topk_cache,
    plan_topk,
    topk_cache_stats,
    trace_count,
)

__all__ = [
    "SKETCH_KINDS",
    "STRATEGIES",
    "TopKConfig",
    "TopKPlan",
    "bisect_shift",
    "clear_topk_cache",
    "count_above",
    "dnc_flops",
    "dnc_topk",
    "gaussian_sketch",
    "needed_power_iters",
    "plan_topk",
    "randomized_range",
    "sketch_flops",
    "sketch_topk",
    "srht_sketch",
    "topk_cache_stats",
    "topk_residual",
    "trace_count",
]
