"""Spectral divide-and-conquer top-k frontend (paper §2.2 turned inward).

Zolo-SVD's eigensolver route (arXiv:1806.06204 Alg. 4 / QDWH-EIG) splits
a symmetric matrix's spectrum with the matrix sign function: for
C = A^T A and a shift s,

    Q = sign(C - s I)           (polar factor of the symmetric
                                 indefinite C - s I — every registered
                                 polar backend computes exactly this)
    P = (I + Q) / 2             (spectral projector onto eigenvalues > s)
    trace(P) = #{ eigenvalues of C above s }.

Full divide-and-conquer recurses on both halves; the *top-k* workload
only ever needs the split point moved until the upper invariant subspace
has width in [k, l]: an in-graph bisection on s, each probe one polar
solve through a cached dynamic :class:`repro.solver.SvdPlan` (the
l0_policy="runtime" path — the shift changes per probe, so conditioning
is only known at execution time).  The bracket comes from
:func:`repro.core.norms.singular_interval` squared (C's spectrum lives
in [sigma_min^2, sigma_max^2]).

Once a window shift is found, subspace extraction is randomized:
V1 = CholeskyQR2(P G) for a Gaussian n x l probe G (P is an orthogonal
projector, so one projected probe + the shifted-ridge orthonormalization
spans range(P) w.h.p., including the k >= rank case where P's rank is
below l and the ridge fills the basis).  Rayleigh-Ritz through
B = A V1 (m x l) is then *exact* — range(V1) contains the leading right
singular subspace, so the small SVD of B returns the true leading
triplets, not approximations.

Contrast with :mod:`repro.spectral.sketch`: d&c accuracy does not depend
on spectral decay (it isolates the window by counting, not by power
iteration), but each probe is a full n x n polar solve and the count is
a *data-dependent* control decision — a cluster of equal singular values
straddling every candidate split leaves no valid window.  That failure
is reported in ``info["converged"]`` rather than silently mis-ranked,
and is why ``strategy="auto"`` in :mod:`repro.spectral.topk` never picks
d&c on its own: the sketch's accuracy model is checkable at plan time,
the d&c's windowability is not.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import norms as _norms
from repro.core.structured_qr import cholesky_qr2


def count_above(q_sign):
    """#{eigenvalues above the shift} from the sign factor: trace of the
    spectral projector (I + Q)/2, i.e. (n + trace(Q)) / 2."""
    n = q_sign.shape[-1]
    return (n + jnp.trace(q_sign, axis1=-2, axis2=-1)) / 2.0


def bisect_shift(c, k: int, l: int, sign_fn, lo2, hi2,
                 max_rounds: int = 12):
    """In-graph bisection for a shift s with k <= trace(P(s)) <= l.

    ``c`` is the (n, n) Gram, ``sign_fn(x) -> sign(x)`` the polar solve
    (the uncompiled impl of a cached dynamic plan, so the whole bisection
    compiles into one executable), [lo2, hi2] the eigenvalue bracket.
    Bisection is geometric — C's spectrum spans kappa^2, so the split
    candidates should be log-uniform, exactly like the Zolotarev
    interval treatment everywhere else in this repo.

    Returns (q_best, shift_best, count_best, converged, rounds).  The
    running best is the *widest window not exceeding l*: if no probe
    lands in [k, l] (clustered spectrum, or rank < k with every
    above-zero count short of k) the caller still gets the projector
    capturing the most leading directions that fit the extraction width.
    """
    n = c.shape[-1]
    dtype = c.dtype
    eps = jnp.finfo(dtype).eps
    lo2 = jnp.maximum(lo2, (eps * jnp.maximum(hi2, 1.0)) ** 2)
    eye = jnp.eye(n, dtype=dtype)

    def probe(shift):
        q = sign_fn(c - shift.astype(dtype) * eye)
        return q, count_above(q)

    # Seed the running best with the lower bracket edge: count there is
    # the closest thing to rank(C) the bracket knows, so the k >= rank
    # fallback is already in hand before the loop refines anything.
    q0, cnt0 = probe(lo2)
    best0 = jnp.where(cnt0 <= l, cnt0, -jnp.inf)

    def cond(state):
        i, lo, hi, _, _, best_cnt, _ = state
        done = (best_cnt >= k) & (best_cnt <= l)
        return (i < max_rounds) & ~done

    def body(state):
        i, lo, hi, q_best, s_best, best_cnt, _ = state
        s = jnp.exp(0.5 * (jnp.log(lo) + jnp.log(hi)))
        q, cnt = probe(s)
        # count too big -> window too wide -> raise the shift
        lo = jnp.where(cnt > l, s, lo)
        hi = jnp.where(cnt < k, s, hi)
        better = (cnt <= l) & (cnt > best_cnt)
        q_best = jnp.where(better, q, q_best)
        s_best = jnp.where(better, s, s_best)
        best_cnt = jnp.where(better, cnt, best_cnt)
        return i + 1, lo, hi, q_best, s_best, best_cnt, i + 1

    state = (jnp.asarray(0), lo2, hi2, q0, lo2, best0, jnp.asarray(0))
    _, _, _, q_best, s_best, best_cnt, rounds = jax.lax.while_loop(
        cond, body, state)
    # -inf best means even the bracket's lower edge over-counted; fall
    # back to that probe so extraction still sees a projector.
    fellback = jnp.isinf(best_cnt)
    q_best = jnp.where(fellback, q0, q_best)
    best_cnt = jnp.where(fellback, cnt0, best_cnt)
    converged = (best_cnt >= k) & (best_cnt <= l)
    return q_best, s_best, best_cnt, converged, rounds


def dnc_topk(a, *, k: int, l: int, key, sign_fn, small_svd,
             max_rounds: int = 12):
    """Leading-k SVD of canonical-tall ``a`` by spectral window + exact
    Rayleigh-Ritz.

    ``sign_fn`` computes the matrix sign of a symmetric (n, n) input
    (dynamic polar plan impl); ``small_svd`` factorizes the (m, l)
    extracted panel.  Returns (u, s, vh, info) with info carrying the
    bisection telemetry (converged / count / shift / rounds).
    """
    n = a.shape[-1]
    dtype = a.dtype
    c = jnp.einsum("...km,...kn->...mn", a, a,
                   preferred_element_type=jnp.promote_types(
                       dtype, jnp.float32)).astype(dtype)
    smin, smax = _norms.singular_interval(a)
    q_sign, shift, cnt, converged, rounds = bisect_shift(
        c, k, l, sign_fn, (smin ** 2).astype(dtype),
        (smax ** 2).astype(dtype) * (1 + 4 * jnp.finfo(dtype).eps),
        max_rounds=max_rounds)

    # Spectral projector -> orthonormal window basis -> Rayleigh-Ritz.
    p = 0.5 * (q_sign + jnp.eye(n, dtype=dtype))
    g = jax.random.normal(key, a.shape[:-2] + (n, l), dtype=dtype)
    v1 = cholesky_qr2(jnp.einsum("...mn,...nl->...ml", p, g,
                                 preferred_element_type=jnp.promote_types(
                                     dtype, jnp.float32)).astype(dtype))
    b = jnp.einsum("...mn,...nl->...ml", a, v1)
    u_b, s, vh_b = small_svd(b)
    u = u_b[..., :, :k]
    vh = jnp.einsum("...kl,...nl->...kn", vh_b[..., :k, :], v1)
    info = {"converged": converged, "count": cnt, "shift": shift,
            "rounds": rounds}
    return u, s[..., :k], vh, info


def dnc_flops(m: int, n: int, k: int, l: int, rounds: int,
              sign_flops: float, small_flops: float = 0.0) -> float:
    """Flop model: Gram + ``rounds`` sign probes (each priced by the
    inner polar backend's own cost model) + projected-probe extraction +
    the (m, l) panel solve."""
    gram = 2.0 * m * n * n
    extract = 2.0 * n * n * l + 2.0 * (2.0 * n * l * l + l ** 3 / 3.0)
    panel = 2.0 * m * n * l
    return (gram + rounds * float(sign_flops) + extract + panel
            + float(small_flops))
