"""Top-k as a first-class workload: ``TopKConfig -> plan_topk -> TopKPlan``.

Same plan/execute discipline as :mod:`repro.solver.planner`, one level
up: a :class:`TopKConfig` is frozen and hashable, ``plan_topk`` resolves
it once per (config, shape, dtype) — strategy selection, sketch width,
power-iteration count, and the *inner* :class:`repro.solver.SvdPlan`
objects all bound at plan time — and the returned :class:`TopKPlan`
executes through a per-plan jit cache, so repeated top-k solves at a
fixed shape perform zero retraces (``trace_count`` asserts it).

Strategy resolution ("auto") is a cost-model argmin over the candidates
whose *accuracy is checkable at plan time*:

* "dense"  — full factorization through the existing solver, sliced to
  k triplets.  Always exact; priced by :func:`repro.solver.
  flops_estimate`, i.e. the same per-backend ``flops_fn`` basis
  ``SvdConfig(method="auto")`` ranks with.
* "sketch" — randomized range finder + O(k)-width panel solve
  (:mod:`repro.spectral.sketch`).  Priced by :func:`~repro.spectral.
  sketch.sketch_flops`; admitted only when :func:`~repro.spectral.
  sketch.needed_power_iters` says the configured tolerance is reachable
  under the conditioning hint — a flat spectrum prices the sketch out
  and auto falls back to dense.

"dnc" (:mod:`repro.spectral.dnc`) is explicit-selection only: its
window bisection is a data-dependent control decision whose success
cannot be certified at plan time, so auto never silently chooses it.

The inner solves reuse the registry stack end to end: the sketch's
panel SVD, the d&c's sign probes (a dynamic ``l0_policy="runtime"``
polar plan) and its Rayleigh-Ritz panel are all cached ``SvdPlan``
objects called through their uncompiled impls, so one ``TopKPlan``
compiles into ONE executable per entry point no matter the strategy.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import registry as _registry
from repro.solver import planner as _planner
from repro.solver.config import SvdConfig
from repro.spectral import dnc as _dnc
from repro.spectral import sketch as _sketch

STRATEGIES = ("auto", "dnc", "sketch", "dense")

_TOPK_MAX = 128
_TOPK_PLANS: "collections.OrderedDict[tuple, TopKPlan]" = \
    collections.OrderedDict()
_STATS = {"traces": 0, "plan_hits": 0, "plan_misses": 0}


def trace_count() -> int:
    """Monotonic count of TopKPlan executable traces (the top-k
    no-retrace contract mirrors :func:`repro.solver.trace_count`)."""
    return _STATS["traces"]


def topk_cache_stats() -> dict:
    return dict(_STATS, plans=len(_TOPK_PLANS))


@dataclasses.dataclass(frozen=True)
class TopKConfig:
    """Frozen description of one top-k workload; hashable plan-cache key.

    k            triplets wanted (1 <= k <= min(shape) at plan time).
    oversample   sketch/window width beyond k: l = k + oversample.  None
                 picks max(8, k, nmin // 16) at plan time — the decay
                 window (l + 1 - k indices) must scale with the problem
                 so per-index decay kappa^(1/nmin) keeps tight
                 tolerances reachable at large nmin.
    power_iters  sketch power iterations; None lets the plan-time
                 accuracy model (:func:`repro.spectral.sketch.
                 needed_power_iters`) choose from (kappa, tol).
    strategy     "auto" | "dnc" | "sketch" | "dense" (see module doc).
    tol          relative accuracy target the plan must certify
                 (drives the sketch feasibility gate and
                 :meth:`TopKPlan.topk_adaptive` escalation).
    kappa        conditioning hint for the accuracy/cost models (falls
                 back to ``svd.kappa``, then 1e6 — same scoring default
                 as the solver planner).
    sketch_kind  "gauss" | "srht" test matrix.
    seed         PRNG seed for the sketch / probe draws (part of the
                 plan key: one plan, one reproducible draw).
    max_power_iters  feasibility ceiling for the accuracy model.
    dnc_rounds   bisection probe budget for strategy="dnc".
    svd          inner :class:`SvdConfig` for every full/panel solve.
    """

    k: int = 8
    oversample: Optional[int] = None
    power_iters: Optional[int] = None
    strategy: str = "auto"
    tol: float = 1e-10
    kappa: Optional[float] = None
    sketch_kind: str = "gauss"
    seed: int = 0
    max_power_iters: int = 12
    dnc_rounds: int = 12
    svd: SvdConfig = SvdConfig()

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"strategy={self.strategy!r} not in {STRATEGIES}")
        if self.sketch_kind not in _sketch.SKETCH_KINDS:
            raise ValueError(f"sketch_kind={self.sketch_kind!r} not in "
                             f"{_sketch.SKETCH_KINDS}")
        if not isinstance(self.svd, SvdConfig):
            raise TypeError(f"svd must be an SvdConfig, "
                            f"got {type(self.svd)}")

    def replace(self, **changes) -> "TopKConfig":
        return dataclasses.replace(self, **changes)


def _dynamic_sign_config(svd: SvdConfig) -> SvdConfig:
    """Inner config for the d&c sign probes: the shifted Gram's
    conditioning is only known at execution time (it depends on the
    probe shift), so the sign solve must be a dynamic
    ``l0_policy="runtime"`` plan.  A static explicitly-chosen inner
    method falls back to method="auto" (the runtime capability filter
    then picks among dynamic backends)."""
    method = svd.method
    if method != "auto" and not _registry.get_polar(method).dynamic:
        method = "auto"
    return svd.replace(method=method, l0_policy="runtime", l0=None,
                       r=None)


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class TopKPlan:
    """A bound top-k solver for one (config, shape, dtype).

    ``topk(a)`` returns (u (m, k), s (k,) descending, vh (k, n));
    ``topk_with_info`` adds the strategy telemetry dict (d&c bisection
    convergence, sketch residual hooks); ``topk_batched`` vmaps over
    leading axes.  ``decision`` records why the strategy was chosen —
    the cost/feasibility numbers auto ranked with.
    """

    config: TopKConfig
    shape: Tuple[int, int]
    dtype: Any
    strategy: str          # resolved ("auto" never survives planning)
    l: int                 # sketch/window width (k + oversample, capped)
    q_iters: int           # resolved sketch power iterations
    decision: Dict[str, Any]
    _transposed: bool
    _inner: Dict[str, Any]      # name -> inner SvdPlan
    _exec: Dict[Any, Any] = dataclasses.field(default_factory=dict)

    @property
    def k(self) -> int:
        return self.config.k

    def __repr__(self):
        return (f"TopKPlan(k={self.k}, strategy={self.strategy!r}, "
                f"l={self.l}, q_iters={self.q_iters}, "
                f"shape={self.shape}, "
                f"dtype={jnp.dtype(self.dtype).name})")

    @property
    def flops_estimate(self) -> Optional[float]:
        return self.decision.get(f"{self.strategy}_flops")

    def audit(self, *, raise_on_fail: bool = True):
        """Lower the whole top-k graph (sketch/d&c + inner solver plans,
        inlined) and walk the jaxpr: the batched path owes the mesh NO
        collectives, no f64 compute under an f32 plan, no host
        callbacks.  See :func:`repro.analysis.jaxpr_audit.audit_plan`."""
        from repro.analysis import jaxpr_audit as _audit

        return _audit.audit_plan(self, raise_on_fail=raise_on_fail)

    # --- traceable implementation -------------------------------------

    def _impl_canonical(self, a):
        """(u, s, vh, info) of canonical-tall ``a`` per the strategy."""
        cfg = self.config
        if self.strategy == "dense":
            u, s, vh = self._inner["dense"]._svd_impl(a)
            return (u[..., :, :self.k], s[..., :self.k],
                    vh[..., :self.k, :], {})
        key = jax.random.PRNGKey(cfg.seed)
        if self.strategy == "sketch":
            u, s, vh = _sketch.sketch_topk(
                a, k=self.k, l=self.l, q_iters=self.q_iters, key=key,
                small_svd=self._inner["panel"]._svd_impl,
                kind=cfg.sketch_kind)
            return u, s, vh, {}
        # dnc
        sign_plan = self._inner["sign"]

        def sign_fn(x):
            return sign_plan._polar_impl(x, want_h=False)[0]

        return _dnc.dnc_topk(
            a, k=self.k, l=self.l, key=key, sign_fn=sign_fn,
            small_svd=self._inner["panel"]._svd_impl,
            max_rounds=cfg.dnc_rounds)

    def _impl(self, a):
        if self._transposed:
            u, s, vh, info = self._impl_canonical(
                jnp.swapaxes(a, -1, -2))
            # a = (u s vh)^T = vh^T s u^T
            return (jnp.swapaxes(vh, -1, -2), s,
                    jnp.swapaxes(u, -1, -2), info)
        return self._impl_canonical(a)

    # --- compiled execution -------------------------------------------

    def _executable(self, key, impl):
        fn = self._exec.get(key)
        if fn is None:
            def traced(a, _impl=impl):
                _STATS["traces"] += 1
                return _impl(a)

            fn = jax.jit(traced)
            self._exec[key] = fn
        return fn

    def _check(self, a, batched=False):
        shape = tuple(a.shape)
        ok = (len(shape) >= 3 and shape[-2:] == self.shape if batched
              else shape == self.shape)
        if not ok:
            expect = (f"(..., {self.shape[0]}, {self.shape[1]})"
                      if batched else str(self.shape))
            raise ValueError(
                f"top-k plan compiled for shape {expect} got {shape}; "
                f"plans are per-shape — build another with "
                f"plan_topk(config, shape, dtype)")
        if jnp.dtype(a.dtype) != jnp.dtype(self.dtype):
            raise ValueError(f"top-k plan compiled for dtype "
                             f"{jnp.dtype(self.dtype).name} got "
                             f"{jnp.dtype(a.dtype).name}")

    def topk_with_info(self, a):
        """(u, s, vh, info) — compiled; info is the strategy telemetry
        (d&c: converged/count/shift/rounds arrays; else empty)."""
        self._check(a)
        return self._executable(("topk",), self._impl)(a)

    def topk(self, a):
        """Leading-k triplets (u, s, vh), s descending — compiled."""
        u, s, vh, _ = self.topk_with_info(a)
        return u, s, vh

    def topk_batched(self, a):
        """``topk`` vmapped over leading axes of (..., m, n) — compiled
        (the serving lane's entry point)."""
        self._check(a, batched=True)

        def run(x):
            lead = x.shape[:-2]
            flat = x.reshape((-1,) + self.shape)
            out = jax.vmap(lambda y: self._impl(y)[:3])(flat)
            return jax.tree.map(
                lambda t: t.reshape(lead + t.shape[1:]), out)

        u, s, vh = self._executable(("topk_batched",), run)(a)
        return u, s, vh

    def residual(self, a, u, s, vh):
        """A-posteriori relative residual of a computed triplet set
        (:func:`repro.spectral.sketch.topk_residual`) — compiled."""
        self._check(a)
        fn = self._exec.get(("residual",))
        if fn is None:
            def traced(x, uu, ss, vvh):
                _STATS["traces"] += 1
                return _sketch.topk_residual(x, uu, ss, vvh)

            fn = jax.jit(traced)
            self._exec[("residual",)] = fn
        return fn(a, u, s, vh)

    def topk_adaptive(self, a, tol: Optional[float] = None):
        """Solve, measure the a-posteriori residual, escalate to the
        exact dense strategy if it misses ``tol``.  Returns
        (u, s, vh, info) with info["escalated"] and info["residual"]
        recording what happened.  Dense solves skip the check — they
        are already exact.

        The dense fallback runs through the resilience escalation
        ladder (:func:`repro.resilience.solve_with_escalation`): the
        first rung is the plan's own dense solve with its in-graph
        health verdict, and an unhealthy dense solve climbs the same
        registry-derived rungs as any serving request.  The rung trail
        is recorded under ``info["trail"]``.

        ``tol`` gates the *residual* (a backward error): by the
        quadratic convergence of Ritz values, residual <= sqrt(tol_val)
        certifies value error <= tol_val, so the default gate is
        sqrt(config.tol)."""
        tol = float(self.config.tol ** 0.5 if tol is None else tol)
        u, s, vh, info = self.topk_with_info(a)
        info = dict(info)
        if self.strategy == "dense":
            info.update(escalated=False, residual=None)
            return u, s, vh, info
        res = float(self.residual(a, u, s, vh))
        info.update(escalated=False, residual=res)
        if not (res <= tol):  # NaN-propagating: a NaN residual (the
            # sketch panel broke down) must escalate, not sail through
            # a False `res > tol` comparison
            # lazy: repro.resilience layers on repro.spectral, not the
            # reverse
            from repro.resilience import escalate as _escalate

            x = jnp.swapaxes(a, -1, -2) if self._transposed else a
            u_f, s_f, vh_f, trail = _escalate.solve_with_escalation(
                x, self._inner["dense"].config)
            uk, sk, vhk = (u_f[..., :, :self.k], s_f[..., :self.k],
                           vh_f[..., :self.k, :])
            if self._transposed:
                u, s, vh = (jnp.swapaxes(vhk, -1, -2), sk,
                            jnp.swapaxes(uk, -1, -2))
            else:
                u, s, vh = uk, sk, vhk
            info.update(escalated=True, trail=trail)
        return u, s, vh, info


def _resolve_topk(config: TopKConfig, shape, dtype):
    m, n = shape
    nmin, nmax = min(m, n), max(m, n)
    transposed = m < n
    can_shape = (nmax, nmin)  # canonical tall orientation
    if config.k > nmin:
        raise ValueError(f"k={config.k} exceeds min(shape)={nmin}; a "
                         f"rank-{nmin} matrix has no more triplets")
    oversample = (max(8, config.k, nmin // 16)
                  if config.oversample is None
                  else int(config.oversample))
    l = min(config.k + oversample, nmin)
    kappa = config.kappa
    if kappa is None:
        kappa = config.svd.kappa
    kappa_eff = float(kappa) if kappa is not None else 1e6

    # Thread the top-k conditioning hint into the inner solver when the
    # caller left it unconfigured: a bare SvdConfig() resolves to a
    # static-schedule backend, which needs the hint to bind l0.
    svd_cfg = config.svd
    if (svd_cfg.kappa is None and svd_cfg.l0 is None
            and svd_cfg.l0_policy == "given"):
        svd_cfg = svd_cfg.replace(kappa=kappa_eff,
                                  l0_policy="estimate_at_plan")

    # --- accuracy gate: can the sketch certify tol at this spectrum? --
    if config.power_iters is not None:
        q_iters: Optional[int] = int(config.power_iters)
        feasible = True  # explicit q: the caller owns the accuracy call
    else:
        q_iters = _sketch.needed_power_iters(nmin, config.k, l,
                                             kappa_eff, config.tol)
        feasible = (q_iters is not None
                    and q_iters <= config.max_power_iters
                    # l = nmin is no sketch at all (no width reduction —
                    # the k ~ n regime); auto hands that to dense even
                    # when the flop count flatters the degenerate sketch
                    and l < nmin)
        if q_iters is None:
            q_iters = config.max_power_iters

    # --- cost models, on the solver's own flops_fn basis --------------
    dense_flops = _planner.flops_estimate(svd_cfg, can_shape, dtype)
    panel_flops = _planner.flops_estimate(svd_cfg, (l, nmin), dtype)
    sketch_flops = _sketch.sketch_flops(
        nmax, nmin, config.k, l, q_iters,
        small_flops=panel_flops or 0.0)

    strategy = config.strategy
    if strategy == "auto":
        if (feasible and dense_flops is not None
                and sketch_flops < dense_flops):
            strategy = "sketch"
        else:
            strategy = "dense"

    decision = {"strategy": strategy, "requested": config.strategy,
                "l": l, "q_iters": q_iters,
                "sketch_feasible": feasible, "kappa": kappa_eff,
                "sketch_flops": sketch_flops,
                "dense_flops": dense_flops}

    # --- bind the inner plans -----------------------------------------
    inner: Dict[str, Any] = {}
    # the dense plan always resolves: it is the adaptive-escalation
    # target and the cost-model baseline (already cached by the
    # flops_estimate call above)
    inner["dense"] = _planner.plan(svd_cfg, can_shape, dtype)
    if strategy == "sketch":
        inner["panel"] = _planner.plan(svd_cfg, (l, nmin), dtype)
    elif strategy == "dnc":
        inner["panel"] = _planner.plan(svd_cfg, (nmax, l), dtype)
        inner["sign"] = _planner.plan(_dynamic_sign_config(svd_cfg),
                                      (nmin, nmin), dtype)
        decision["dnc_flops"] = _dnc.dnc_flops(
            nmax, nmin, config.k, l, config.dnc_rounds,
            sign_flops=inner["sign"].flops_estimate or 0.0,
            small_flops=inner["panel"].flops_estimate or 0.0)
    return TopKPlan(config=config, shape=tuple(shape), dtype=dtype,
                    strategy=strategy, l=l, q_iters=q_iters,
                    decision=decision, _transposed=transposed,
                    _inner=inner)


def plan_topk(config: TopKConfig, shape, dtype=None) -> TopKPlan:
    """Resolve ``config`` at (shape, dtype) into a cached TopKPlan.

    Identical (config, shape, dtype) return the same plan object whose
    compiled executables are reused — the compile-once / run-many
    contract, one level above :func:`repro.solver.plan`.  ``dtype``
    defaults to the widest enabled float (f64 under jax_enable_x64).
    """
    if not isinstance(config, TopKConfig):
        raise TypeError(
            f"plan_topk() takes a TopKConfig, got {type(config)}")
    shape = tuple(int(s) for s in shape)
    if len(shape) != 2:
        raise ValueError(f"plan_topk() takes the 2-D problem shape "
                         f"(m, n), got {shape}")
    if dtype is None:
        dtype = jnp.result_type(float)
    dtype = jnp.dtype(dtype)
    key = (config, shape, dtype)
    cached = _TOPK_PLANS.get(key)
    if cached is not None:
        _STATS["plan_hits"] += 1
        _TOPK_PLANS.move_to_end(key)
        return cached
    _STATS["plan_misses"] += 1
    built = _resolve_topk(config, shape, dtype)
    _TOPK_PLANS[key] = built
    while len(_TOPK_PLANS) > _TOPK_MAX:
        _TOPK_PLANS.popitem(last=False)
    return built


def clear_topk_cache() -> None:
    _TOPK_PLANS.clear()
