from repro.data.pipeline import SyntheticLM
