"""Deterministic synthetic LM data pipeline.

Design constraints for 1000+ node runs (DESIGN.md §4):

* **Stateless / deterministic-by-step**: batch(step) is a pure function of
  (seed, step), so a replacement node reproduces any shard without
  coordination, restarts need no data-state checkpoint, and stragglers can
  be re-assigned work idempotently.
* **Sharded placement**: arrays are placed with the mesh's batch sharding
  (device_put with a NamedSharding); in multi-process deployments each
  process materializes only its addressable shards
  (``jax.make_array_from_callback`` path).

The token stream is a Zipf-ish categorical derived from a counter-mode
hash — cheap, reproducible, and with a non-uniform unigram distribution so
losses behave qualitatively like text."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import LogicalRules, logical_sharding


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_prefix_embeds: int = 0
    d_model: int = 0
    dtype: str = "bfloat16"
    mesh: Optional[object] = None
    rules: Optional[LogicalRules] = None

    def _tokens_np(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # Zipf-ish: square a uniform to skew mass toward small ids
        u = rng.random((self.global_batch, self.seq_len))
        toks = (u * u * (self.vocab_size - 1)).astype(np.int32)
        return toks

    def batch_at(self, step: int):
        toks = self._tokens_np(step)
        batch = {"tokens": jnp.asarray(toks)}
        if self.num_prefix_embeds:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed + 1, step]))
            emb = rng.standard_normal(
                (self.global_batch, self.num_prefix_embeds, self.d_model))
            batch["embeds"] = jnp.asarray(emb, jnp.dtype(self.dtype))
        if self.mesh is not None and self.rules is not None:
            shardings = {
                "tokens": logical_sharding(self.mesh, self.rules,
                                           ("batch", "seq")),
                "embeds": logical_sharding(self.mesh, self.rules,
                                           ("batch", "seq", None)),
            }
            batch = {k: jax.device_put(v, shardings[k])
                     for k, v in batch.items()}
        return batch
