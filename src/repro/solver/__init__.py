"""repro.solver — the plan/execute SVD surface.

    cfg  = SvdConfig(method="auto", kappa=1e8, l0_policy="estimate_at_plan")
    p    = plan(cfg, a.shape, a.dtype)        # resolve + precompute once
    u, s, vh = p.svd(a)                       # compiled; repeats don't retrace

Method/mode/r selection, schedule precomputation, mesh binding, and the
compiled-executable cache live in :mod:`repro.solver.planner`; the
frozen configuration in :mod:`repro.solver.config`.  Backends register in
:mod:`repro.core.registry` (capability flags + ``flops_fn``/``plan_fn``
plan-time hooks) — never with if/elif chains.
"""

import repro.core  # noqa: F401  (populates the solver registry)
from repro.solver.config import SvdConfig
from repro.solver.planner import (
    PlanResolution,
    SvdPlan,
    cache_stats,
    clear_plan_cache,
    flops_estimate,
    pin,
    plan,
    plan_cache_stats,
    plan_for_call,
    set_plan_cache_capacity,
    trace_count,
    unpin,
)

__all__ = [
    "PlanResolution",
    "SvdConfig",
    "SvdPlan",
    "cache_stats",
    "clear_plan_cache",
    "flops_estimate",
    "pin",
    "plan",
    "plan_cache_stats",
    "plan_for_call",
    "set_plan_cache_capacity",
    "trace_count",
    "unpin",
]
