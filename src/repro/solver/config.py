"""SvdConfig: the frozen, hashable description of one solver configuration.

The paper's pipeline is plan-then-run: pick the Zolotarev order r from
the condition number (Table 1), build the coefficient schedule once,
allocate the r process-group contexts, then iterate.  ``SvdConfig``
captures everything that selection depends on — method, execution mode,
r, the ``l0`` policy, QR-regime knobs, eig backend, dtype policy — as a
frozen dataclass, so a config is a dict key: ``repro.solver.plan()`` caches
one compiled executable per (shape, dtype, config) and repeated solves
never retrace.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

MODES = ("auto", "static", "dynamic", "grouped")
L0_POLICIES = ("given", "estimate_at_plan", "runtime")
SCALES = ("none", "power", "bound")


@dataclasses.dataclass(frozen=True)
class SvdConfig:
    """Frozen solver configuration; hashable, so it keys the plan cache.

    method       registry polar backend name, or "auto" (capability flags
                 + per-spec ``flops_fn`` cost model pick the cheapest).
    eig_method   registry eigensolver for the H-stage of Algorithm 2.
    mode         "static" (trace-time schedule), "dynamic" (runtime
                 conditioning in-graph), "grouped" (Algorithm 3 over a
                 ("zolo", "sep") mesh), or "auto": grouped when a mesh is
                 passed to ``plan``, dynamic when ``l0_policy`` is
                 "runtime", else static.  With an explicit (non-"auto")
                 method, "auto" simply follows that backend's nature.
    r            Zolotarev order / process-group count; None picks it
                 from the conditioning per paper Table 1 (``choose_r``).
    l0           lower bound on sigma_min of the (pre-scaled) input.
    l0_policy    "given" (use ``l0`` as supplied), "estimate_at_plan"
                 (derive ``l0 = 0.9 / kappa`` from the ``kappa`` hint at
                 plan time), or "runtime" (a dynamic backend estimates
                 the bound in-graph; ``l0`` must be None).  "runtime"
                 combined with ``mesh=`` resolves to a grouped-capable
                 dynamic backend (``zolo_grouped_dynamic``: the bound is
                 estimated sep-collectively in-graph), so one compiled
                 grouped executable serves any conditioning.
    kappa        condition-number hint used by plan-time selection
                 (auto method scoring, r choice, l0 estimation).
    max_iters    schedule length cap; None keeps each backend's default.
    qr_mode      stable-regime factorization for the first iterations
                 ("cholqr2" | "householder" | "chol"); None keeps the
                 backend default (Zolo family: "cholqr2").
    qr_iters     how many leading iterations use ``qr_mode``; None keeps
                 the backend default (Zolo family: 1; QDWH: its
                 c_k > 100 switching heuristic).
    nb           block size for the block-Jacobi eigensolver.
    scale        in-graph pre-scaling applied by the plan for backends
                 with trace-time schedules (dynamic backends self-scale):
                 "power" (default: sharp 1.05x power-iteration bound —
                 the ZoloMuon setting; safe for un-normalized inputs and
                 compatible with the 0.9 safety in estimated l0),
                 "bound" (guaranteed sqrt(norm1*norminf) cap), "none"
                 (NO scaling: the caller guarantees sigma_max <= 1 with
                 singular values in [l0, 1] — a static plan fed a larger
                 matrix under "none" silently loses accuracy; the legacy
                 ``polar_svd``/``polar_decompose`` wrappers pin "none"
                 because their callers always pre-scaled).
    compute_dtype  factorize in this dtype, cast results back to the
                 plan dtype; None computes in the input dtype.
    extra        extra backend kwargs as a sorted tuple of (name, value)
                 pairs — the hashable passthrough for knobs the config
                 does not model (e.g. ``alpha`` for dynamic drivers).
                 One key is reserved for the planner itself:
                 ``comm_flops_per_word`` (the psum calibration measured
                 by ``benchmarks/comm_calibrate.py``) threads into every
                 cost-model scoring call and never reaches the backend.
    """

    method: str = "auto"
    eig_method: str = "eigh"
    mode: str = "auto"
    r: Optional[int] = None
    l0: Optional[float] = None
    l0_policy: str = "given"
    kappa: Optional[float] = None
    max_iters: Optional[int] = None
    qr_mode: Optional[str] = None
    qr_iters: Optional[int] = None
    nb: int = 32
    scale: str = "power"
    compute_dtype: Optional[str] = None
    extra: Tuple[Tuple[str, object], ...] = ()

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode={self.mode!r} not in {MODES}")
        if self.l0_policy not in L0_POLICIES:
            raise ValueError(
                f"l0_policy={self.l0_policy!r} not in {L0_POLICIES}")
        if self.scale not in SCALES:
            raise ValueError(f"scale={self.scale!r} not in {SCALES}")
        if self.l0_policy == "runtime" and self.l0 is not None:
            raise ValueError("l0_policy='runtime' estimates the bound "
                             "in-graph; leave l0=None (or use 'given')")
        extra = self.extra
        if isinstance(extra, dict):
            extra = extra.items()
        extra = tuple(sorted((str(k), v) for k, v in extra))
        try:
            hash(extra)
        except TypeError:
            raise ValueError(
                "SvdConfig.extra must be hashable (plan configs key the "
                "executable cache); pass array-valued kwargs at call "
                f"time instead: {extra!r}") from None
        object.__setattr__(self, "extra", extra)

    def replace(self, **changes) -> "SvdConfig":
        """A copy with the given fields replaced (configs are frozen)."""
        return dataclasses.replace(self, **changes)
