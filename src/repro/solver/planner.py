"""plan/execute engine: ``plan(SvdConfig, shape, dtype, mesh) -> SvdPlan``.

The paper's solver is plan-then-run: r is chosen from the condition
number (Table 1), the Zolotarev coefficient schedule is built once, the r
process-group contexts are allocated, and only then does the iteration
touch the matrix.  ``plan`` performs exactly those steps at trace time —
method resolution through the registry's capability flags and per-spec
``flops_fn`` cost model, schedule precomputation through the spec's
``plan_fn``, mesh binding for grouped (Algorithm 3) execution — and
returns an :class:`SvdPlan` whose ``svd`` / ``polar`` / ``svd_batched``
entry points run compiled executables cached per (shape, dtype, config):
repeated solves at a fixed shape never retrace.

``polar_svd`` / ``polar_decompose`` in :mod:`repro.core.svd` are thin
back-compat wrappers over this same path (via :func:`plan_for_call`), so
there is still exactly one dispatch route from any public entry point
down to a registered backend.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  (imported for its backend registrations)
from repro.core import coeffs as _coeffs
from repro.core import norms as _norms
from repro.core import registry as _registry
from repro.core import zolo as _zolo
from repro.solver.config import SvdConfig

_UNSET = object()  # "leave want_h to the backend's default" sentinel

# LRU-bounded: the back-compat wrappers fold data-dependent floats (e.g.
# l=0.9/kappa) into the config key, so a long-running caller sweeping
# conditioning values must not accumulate plans (and their compiled
# executables) without bound.  128 distinct live configurations is far
# beyond any in-repo workload; hot plans are kept by the LRU order.
_PLANS_MAX = 128
_PLANS: "collections.OrderedDict[tuple, SvdPlan]" = collections.OrderedDict()
_PINNED: set = set()  # plan keys exempt from LRU eviction
_STATS = {"traces": 0, "plan_hits": 0, "plan_misses": 0, "evictions": 0}


def trace_count() -> int:
    """Total backend traces performed by plan executables (monotonic).

    A repeated ``plan.svd`` call at a fixed (shape, dtype, config) must
    not move this counter — that is the no-retrace contract tests assert.
    """
    return _STATS["traces"]


def plan_cache_stats() -> dict:
    return dict(_STATS, plans=len(_PLANS))


def cache_stats() -> dict:
    """Public plan-cache counters: the serving observability surface.

    ``hits``/``misses``/``evictions`` are monotonic; ``size`` is live
    plans, ``pinned`` of those exempt from LRU eviction, ``capacity``
    the LRU bound (see :func:`set_plan_cache_capacity`).  A service
    measures steady-state hit rate as the hits/(hits+misses) delta
    between two snapshots.
    """
    return {"hits": _STATS["plan_hits"], "misses": _STATS["plan_misses"],
            "evictions": _STATS["evictions"], "size": len(_PLANS),
            "pinned": len(_PINNED), "capacity": _PLANS_MAX}


def _plan_key(p: "SvdPlan") -> tuple:
    return (p.config, p.shape, jnp.dtype(p.dtype), p.mesh)


def pin(p: "SvdPlan") -> None:
    """Exempt a plan from LRU eviction (a service's warmed bucket set
    must survive cache pressure from other tenants).  Idempotent; the
    plan re-enters the cache if it was already evicted."""
    key = _plan_key(p)
    _PLANS.setdefault(key, p)
    _PINNED.add(key)


def unpin(p: "SvdPlan") -> None:
    """Return a pinned plan to normal LRU lifetime.  Idempotent."""
    _PINNED.discard(_plan_key(p))


def set_plan_cache_capacity(n: int) -> int:
    """Set the LRU bound (returns the previous one), evicting now if the
    cache is over it.  Pinned plans never count toward eviction order
    but do occupy ``size`` — capacity below the pinned count keeps every
    pin and nothing else."""
    global _PLANS_MAX
    if n < 1:
        raise ValueError(f"plan cache capacity must be >= 1, got {n}")
    prev, _PLANS_MAX = _PLANS_MAX, int(n)
    _evict()
    return prev


def _evict() -> None:
    over = len(_PLANS) - _PLANS_MAX
    if over <= 0:
        return
    for key in list(_PLANS):  # OrderedDict: least-recently-used first
        if over <= 0:
            break
        if key in _PINNED:
            continue
        del _PLANS[key]
        _STATS["evictions"] += 1
        over -= 1


def clear_plan_cache() -> None:
    """Drop all cached plans (and their compiled executables), pins
    included.  Does not reset counters — they are monotonic."""
    _PLANS.clear()
    _PINNED.clear()


@dataclasses.dataclass(frozen=True)
class PlanResolution:
    """Everything a spec's ``plan_fn`` may bind static kwargs from."""

    method: str
    mode: str
    eig_method: str
    m: int
    n: int
    dtype: Any
    r: Optional[int]
    l0: Optional[float]
    kappa: Optional[float]  # resolved hint (config.kappa, 1/l0, or None)
    max_iters: Optional[int]
    qr_mode: Optional[str]   # None -> backend default
    qr_iters: Optional[int]  # None -> backend default
    nb: int
    # grouped (Alg. 3) mesh factorization ndev = r * sep: the intra-group
    # distribution degree (size of the mesh's "sep" axis; 1 otherwise)
    sep: int = 1
    # the config's compute_dtype resolved to a jnp.dtype (None: compute
    # in the plan dtype).  plan_fns that gate on precision — e.g. the
    # Pallas envelope check — must key on this, not ``dtype``: it names
    # the precision the kernels actually see.
    compute_dtype: Any = None


# config knobs routed through plan_fn, and the output keys that count as
# consuming them (a schedule subsumes the bounds it was built from; the
# dynamic drivers bind l0 as their l= override and qr_mode as the peeled
# first iteration's first_mode=)
_KNOB_CONSUMED_AS = {
    "r": ("r", "schedule"),
    "l0": ("l0", "l", "schedule"),
    "max_iters": ("max_iters", "schedule"),
    "qr_mode": ("qr_mode", "first_mode"),
    "qr_iters": ("qr_iters",),
}


def _capability_ok(spec, mode: str, runtime_l0: bool = False) -> bool:
    # auto never picks reference oracles or comparison baselines — they
    # stay reachable by explicit method= only
    if spec.is_oracle or spec.baseline:
        return False
    if runtime_l0 and not spec.dynamic:
        # the in-graph bound estimate needs a runtime-conditioning
        # backend in every mode (a grouped static schedule cannot
        # consume a bound that only exists at execution time)
        return False
    if mode == "grouped":
        return spec.supports_grouped
    if spec.requires_mesh:
        return False
    return spec.dynamic if mode == "dynamic" else not spec.dynamic


def _dynamic_methods(mesh_bound: bool) -> list:
    """Registered dynamic backends, restricted to grouped-capable ones
    when the caller's plan is mesh-bound — an error message listing
    methods the mesh could never run would send the caller in circles."""
    names = [n for n in _registry.list_polar()
             if _registry.get_polar(n).dynamic]
    if mesh_bound:
        return [n for n in names
                if _registry.get_polar(n).supports_grouped]
    # no mesh: a grouped-only backend is equally unreachable
    return [n for n in names if not _registry.get_polar(n).requires_mesh]


def _select_method(mode: str, m: int, n: int, r_hint: int,
                   kappa: float, dtype=None, sep: int = 1,
                   runtime_l0: bool = False, comm_flops_per_word=None):
    """method="auto": capability filter, then cheapest by ``flops_fn``.

    ``sep`` is the grouped mesh's intra-group distribution degree: the
    cost model divides each group's Gram/solve work by it (plus a psum
    communication term), so auto scoring ranks grouped backends by their
    true per-device critical path on the (r, sep) mesh.
    ``runtime_l0`` restricts candidates to dynamic backends (the
    l0_policy="runtime" bound only exists at execution time), and
    ``comm_flops_per_word`` threads a calibrated psum cost
    (``SvdConfig.extra``; see ``benchmarks/comm_calibrate.py``) into
    every cost model.
    """
    cands = [_registry.get_polar(name) for name in _registry.list_polar()]
    cands = [s for s in cands if _capability_ok(s, mode, runtime_l0)]
    if not cands:
        raise ValueError(f"no registered polar backend supports "
                         f"mode={mode!r}" +
                         (" with l0_policy='runtime'" if runtime_l0
                          else ""))
    comm_kw = ({} if comm_flops_per_word is None
               else {"comm_flops_per_word": comm_flops_per_word})

    def score(spec):
        if spec.flops_fn is None:
            return (1, 0.0, spec.name)  # unranked: after every costed spec
        flops = float(spec.flops_fn(m, n, r=r_hint, kappa=kappa,
                                    grouped=(mode == "grouped"),
                                    dtype=dtype, sep=sep, **comm_kw))
        if mode == "grouped":
            flops /= max(r_hint, 1)  # per-group critical path
        return (0, flops, spec.name)

    return min(cands, key=score)


def _validate_capability(spec, mode: str, config: SvdConfig,
                         mesh_bound: bool = False) -> None:
    if mode == "grouped":
        if not spec.supports_grouped:
            grouped = [n for n in _registry.list_polar()
                       if _registry.get_polar(n).supports_grouped]
            raise ValueError(
                f"polar method {spec.name!r} does not support grouped "
                f"(mesh=) execution; grouped-capable methods: {grouped}")
        if config.l0_policy == "runtime" and not spec.dynamic:
            raise ValueError(
                f"l0_policy='runtime' estimates the bound in-graph, "
                f"which needs a runtime-conditioning backend; "
                f"{spec.name!r} binds a trace-time schedule "
                f"(grouped-capable dynamic methods: "
                f"{_dynamic_methods(mesh_bound=True)})")
        return
    if spec.requires_mesh:
        raise ValueError(f"polar method {spec.name!r} runs grouped only; "
                         f"pass mesh=zolo_group_mesh(r)")
    if mode == "dynamic" and not spec.dynamic and not spec.is_oracle:
        raise ValueError(
            f"polar method {spec.name!r} has a trace-time schedule; "
            f"mode='dynamic' needs a runtime-conditioning backend "
            f"(registered dynamic methods: "
            f"{_dynamic_methods(mesh_bound)})")
    if mode == "static" and spec.dynamic and config.mode != "auto":
        raise ValueError(
            f"polar method {spec.name!r} is a dynamic (runtime "
            f"conditioning) backend; mode='static' needs a trace-time "
            f"schedule — use mode='dynamic' or 'auto'")
    if config.l0_policy == "runtime" and not spec.dynamic:
        raise ValueError(
            f"l0_policy='runtime' estimates the bound in-graph, which "
            f"needs a dynamic backend; {spec.name!r} is static "
            f"(registered dynamic methods: "
            f"{_dynamic_methods(mesh_bound)})")


def _resolve(config: SvdConfig, shape, dtype, mesh):
    m, n = shape
    explicit = (None if config.method == "auto"
                else _registry.get_polar(config.method))
    eig_spec = _registry.get_eig(config.eig_method)  # fail fast on typos

    # --- mode ---------------------------------------------------------
    mode = config.mode
    if mode == "auto":
        if mesh is not None:
            mode = "grouped"
        elif explicit is not None:
            mode = "dynamic" if explicit.dynamic else "static"
        elif config.l0_policy == "runtime":
            mode = "dynamic"
        else:
            mode = "static"
    if mode == "grouped" and mesh is None:
        raise ValueError("mode='grouped' needs mesh=zolo_group_mesh(r)")
    if mode != "grouped" and mesh is not None:
        raise ValueError(f"mesh= implies grouped execution but "
                         f"mode={mode!r}; use mode='grouped' or 'auto'")

    # --- l0 / kappa ---------------------------------------------------
    l0 = config.l0
    if l0 is None and config.l0_policy == "estimate_at_plan":
        if config.kappa is None:
            raise ValueError("l0_policy='estimate_at_plan' derives l0 "
                             "from the conditioning; set SvdConfig.kappa")
        l0 = 0.9 / float(config.kappa)
    kappa = config.kappa
    if kappa is None and l0 is not None:
        kappa = 1.0 / float(l0)
    kappa_eff = kappa if kappa is not None else 1e6  # scoring default

    # --- r / sep (paper Table 1 via choose_r, or the mesh's (r, sep)
    #     factorization of the device count) ---------------------------
    r = config.r
    sep = 1
    if mode == "grouped":
        mesh_r = None
        try:
            mesh_r = int(mesh.shape["zolo"])
        except Exception:
            pass  # capability check below rejects non-grouped specs
        try:
            sep = int(mesh.shape["sep"])
        except Exception:
            sep = 1  # custom mesh without an intra-group axis
        if mesh_r is not None and mesh_r * sep != mesh.size:
            raise ValueError(
                f"grouped execution lays ndev = r * sep out as the "
                f"('zolo', 'sep') factorization; mesh axes "
                f"{dict(mesh.shape)} do not factor its {mesh.size} "
                f"devices — build the mesh with zolo_group_mesh(r)")
        if r is None:
            r = mesh_r
        elif mesh_r is not None and mesh_r != r:
            raise ValueError(f"config.r={r} but the mesh 'zolo' axis has "
                             f"size {mesh_r}")
        if sep > 1 and config.qr_mode == "householder" and \
                (config.qr_iters is None or config.qr_iters > 0):
            # fail at plan time, not at first execution: the structured
            # Householder first iteration needs the full iterate on
            # every device (see grouped_zolo_pd_static)
            raise ValueError(
                f"qr_mode='householder' is not row-distributable over "
                f"the sep={sep} intra-group axis; use a sep=1 mesh "
                f"(r == ndev) or qr_mode='cholqr2'")
    elif r is None and kappa is not None:
        r = _coeffs.choose_r(kappa_eff)

    # --- method -------------------------------------------------------
    # comm_flops_per_word is a cost-model calibration (see
    # benchmarks/comm_calibrate.py), not a backend kwarg: it is consumed
    # here, at scoring time, and never reaches the driver
    comm_word = dict(config.extra).get("comm_flops_per_word")
    # scoring (and envelope) precision is the one the backend computes
    # in: compute_dtype when the config sets one, the plan dtype
    # otherwise — a bf16 compute plan over f32 inputs must be priced
    # (and envelope-capped) as bf16
    compute_dtype = (jnp.dtype(config.compute_dtype)
                     if config.compute_dtype is not None else None)
    score_dtype = compute_dtype if compute_dtype is not None else dtype
    if explicit is not None:
        spec = explicit
    else:
        spec = _select_method(mode, m, n,
                              r or _coeffs.choose_r(kappa_eff), kappa_eff,
                              dtype=score_dtype, sep=sep,
                              runtime_l0=(config.l0_policy == "runtime"),
                              comm_flops_per_word=comm_word)
    _validate_capability(spec, mode, config, mesh_bound=(mesh is not None))

    res = PlanResolution(method=spec.name, mode=mode,
                         eig_method=eig_spec.name, m=m, n=n, dtype=dtype,
                         r=r, l0=l0, kappa=kappa,
                         max_iters=config.max_iters,
                         qr_mode=config.qr_mode, qr_iters=config.qr_iters,
                         nb=config.nb, sep=sep,
                         compute_dtype=compute_dtype)

    # --- static kwargs -------------------------------------------------
    # extras pass through verbatim (a kwarg a backend does not accept
    # still reaches it and fails loudly, as a direct call would); config
    # knobs flow through the spec's plan_fn, which re-emits what the
    # backend takes (possibly under another name — l0 becomes a
    # schedule).  An explicitly-set knob the plan_fn does not consume is
    # a configuration error, reported here instead of being dropped.
    backend_kwargs = dict(config.extra)
    backend_kwargs.pop("comm_flops_per_word", None)  # scoring-only knob
    if spec.plan_fn:
        emitted = dict(spec.plan_fn(res))
        for knob, aliases in _KNOB_CONSUMED_AS.items():
            if getattr(config, knob) is not None and \
                    not any(a in emitted for a in aliases):
                raise ValueError(
                    f"polar method {spec.name!r} does not use {knob}=; "
                    f"its plan binds {sorted(emitted)}")
        backend_kwargs.update(emitted)
    else:
        # no plan_fn: explicitly-set knobs pass to the backend verbatim
        for knob in _KNOB_CONSUMED_AS:
            value = getattr(config, knob)
            if value is not None:
                backend_kwargs.setdefault(knob, value)
    eig_kwargs = {"nb": res.nb}
    if eig_spec.plan_fn:
        eig_kwargs.update(eig_spec.plan_fn(res))
    return spec, eig_spec, res, backend_kwargs, eig_kwargs


@dataclasses.dataclass(frozen=True, eq=False, repr=False)
class SvdPlan:
    """A bound solver: resolved config + precomputed schedule + compiled
    executables for one (shape, dtype, config, mesh).

    ``svd(a)`` / ``polar(a, want_h=)`` execute the 2-D problem the plan
    was built for; ``svd_batched`` / ``polar_batched`` vmap the same
    executable over leading axes (not available for grouped plans).  All
    entry points run through a per-plan jit cache, so the second call at
    the planned shape performs zero retraces.
    """

    config: SvdConfig
    shape: Tuple[int, int]
    dtype: Any
    mesh: Any
    resolution: PlanResolution
    _spec: Any
    _eig_spec: Any
    _backend_kwargs: Dict[str, Any]
    _eig_kwargs: Dict[str, Any]
    _exec: Dict[Any, Any] = dataclasses.field(default_factory=dict)

    # --- introspection ------------------------------------------------

    @property
    def method(self) -> str:
        return self.resolution.method

    @property
    def mode(self) -> str:
        return self.resolution.mode

    @property
    def r(self) -> Optional[int]:
        return self.resolution.r

    @property
    def sep(self) -> int:
        """Intra-group distribution degree of the grouped mesh (size of
        its "sep" axis; 1 for non-grouped plans): the recorded (r, sep)
        factorization is ndev = plan.r * plan.sep."""
        return self.resolution.sep

    @property
    def l0(self) -> Optional[float]:
        return self.resolution.l0

    @property
    def eig_method(self) -> str:
        return self.resolution.eig_method

    @property
    def schedule(self):
        """The precomputed trace-time schedule bound by the spec's
        ``plan_fn`` (None for dynamic backends)."""
        return self._backend_kwargs.get("schedule")

    @property
    def flops_estimate(self) -> Optional[float]:
        """Flop estimate from the spec's ``flops_fn``, on the same basis
        ``method="auto"`` scores with: total serial flops, or the
        per-group (per-device, for sep > 1 meshes) critical path
        (total / r with the group's work divided over sep) for grouped
        plans.  None when the backend registers no cost model."""
        if self._spec.flops_fn is None:
            return None
        res = self.resolution
        kappa = res.kappa if res.kappa is not None else 1e6
        r = res.r if res.r is not None else _coeffs.choose_r(kappa)
        grouped = self.mode == "grouped"
        comm_word = dict(self.config.extra).get("comm_flops_per_word")
        comm_kw = ({} if comm_word is None
                   else {"comm_flops_per_word": comm_word})
        score_dtype = (res.compute_dtype if res.compute_dtype is not None
                       else res.dtype)
        flops = float(self._spec.flops_fn(res.m, res.n, r=r, kappa=kappa,
                                          grouped=grouped,
                                          dtype=score_dtype, sep=res.sep,
                                          **comm_kw))
        return flops / max(r, 1) if grouped else flops

    def audit(self, *, raise_on_fail: bool = True):
        """Lower the plan's traceable impl and walk the jaxpr for graph
        invariants: psum count/axes per grouped iteration (the PR 4
        double-reduction class), f64 discipline under ``compute_dtype``,
        and no host callbacks.  Returns an
        :class:`repro.analysis.jaxpr_audit.AuditReport`; raises
        ``AuditError`` on violations unless ``raise_on_fail=False``."""
        from repro.analysis import jaxpr_audit as _audit

        return _audit.audit_plan(self, raise_on_fail=raise_on_fail)

    def __repr__(self):
        sep = f"sep={self.sep}, " if self.mode == "grouped" else ""
        return (f"SvdPlan(method={self.method!r}, mode={self.mode!r}, "
                f"r={self.r}, {sep}l0={self.l0}, shape={self.shape}, "
                f"dtype={jnp.dtype(self.dtype).name}, "
                f"eig={self.eig_method!r})")

    def _is_current(self) -> bool:
        """Cached plans go stale if their backend was re-registered."""
        try:
            return (_registry.get_polar(self.method) is self._spec
                    and _registry.get_eig(self.eig_method)
                    is self._eig_spec)
        except ValueError:
            return False

    # --- traceable implementations (shared with the back-compat
    #     wrappers in repro.core.svd, which call them uncompiled) ------

    def _prescale(self, x):
        if self.config.scale == "power":
            # sharp 1.05x power-iteration bound (the ZoloMuon setting)
            alpha = 1.05 * _norms.sigma_max_power(x, iters=8) + 1e-12
        else:  # "bound": guaranteed upper bound
            alpha = _norms.sigma_max_upper(x)
        alpha = jnp.asarray(alpha)
        return (x / alpha.astype(x.dtype)).astype(x.dtype), alpha

    def _polar_canonical(self, a, want_h, extra=None):
        """Run the backend on the canonical (m >= n) orientation.

        Returns (q, h, info, transposed, alpha, out_dtype) with q/h still
        canonical and h of the *scaled* input when ``alpha`` is not None.
        """
        kw = dict(self._backend_kwargs)
        if extra:
            kw.update(extra)
        if want_h is not _UNSET:
            kw["want_h"] = want_h
        a_work, transposed = _zolo.polar_canonical(a)
        out_dtype = a_work.dtype
        if self.config.compute_dtype is not None:
            a_work = a_work.astype(jnp.dtype(self.config.compute_dtype))
        alpha = None
        if (self.config.scale != "none" and not self._spec.dynamic
                and not self._spec.is_oracle):
            # trace-time-schedule backends assume sigma_max <= 1; dynamic
            # backends estimate their own alpha in-graph
            a_work, alpha = self._prescale(a_work)
        if self.mode == "grouped":
            q, h, info = self._spec.grouped_fn(a_work, mesh=self.mesh,
                                               **kw)
        else:
            q, h, info = self._spec.fn(a_work, **kw)
        return q, h, info, transposed, alpha, out_dtype

    def _polar_impl(self, a, want_h=_UNSET, extra=None):
        q, h, info, transposed, alpha, out_dtype = \
            self._polar_canonical(a, want_h, extra)
        if h is not None and alpha is not None:
            h = h * alpha.astype(h.dtype)
        if transposed:
            if h is not None:
                # A = (Q_w H_w)^T = H_w Q_w^T; right factor
                # H = Q_w H_w Q_w^T satisfies A = Q_w^T H, H (n, n) PSD.
                h = jnp.einsum("...ik,...kl,...jl->...ij", q, h, q)
            q = jnp.swapaxes(q, -1, -2)
        q = q.astype(out_dtype)
        if h is not None:
            h = h.astype(out_dtype)
        return q, h, info

    def _svd_impl(self, a, extra=None):
        u, s, vh, _ = self._svd_impl_info(a, extra)
        return u, s, vh

    def _svd_impl_info(self, a, extra=None):
        q, h, info, transposed, alpha, out_dtype = \
            self._polar_canonical(a, True, extra)
        # lax.linalg has no sub-f32 eigensolver kernels: a bf16 compute
        # plan hands H to the eig stage at the accumulation precision
        # (no-op for f32/f64 — promote_types is the identity there)
        h = h.astype(jnp.promote_types(h.dtype, jnp.float32))
        w, v = self._eig_spec.fn(h, **self._eig_kwargs)
        u = jnp.einsum("...mk,...kn->...mn", q, v)
        # ascending -> descending; fold any tiny negative eigenvalue's
        # sign into U so that s >= 0.
        sign = jnp.where(w < 0, -1.0, 1.0).astype(u.dtype)
        s = jnp.abs(w)
        if alpha is not None:
            s = s * alpha.astype(s.dtype)
        u = u * sign[..., None, :]
        order = jnp.argsort(-s, axis=-1)
        s = jnp.take_along_axis(s, order, axis=-1)
        u = jnp.take_along_axis(u, order[..., None, :], axis=-1)
        v = jnp.take_along_axis(v, order[..., None, :], axis=-1)
        vh = jnp.swapaxes(v, -1, -2)
        u = u.astype(out_dtype)
        s = s.astype(out_dtype)
        vh = vh.astype(out_dtype)
        if transposed:
            # a = (u s vh)^T = v s u^T
            return vh.swapaxes(-1, -2), s, jnp.swapaxes(u, -1, -2), info
        return u, s, vh, info

    def _svd_verified_impl(self, a, extra=None):
        # lazy: repro.resilience layers on repro.solver, not the reverse
        from repro.resilience import health as _rhealth

        u, s, vh, info = self._svd_impl_info(a, extra)
        return u, s, vh, _rhealth.solve_health(u, s, vh, info)

    # --- compiled execution -------------------------------------------

    def _executable(self, key, impl):
        fn = self._exec.get(key)
        if fn is None:
            def traced(a, _impl=impl):
                _STATS["traces"] += 1
                return _impl(a)

            fn = jax.jit(traced)
            self._exec[key] = fn
        return fn

    def _check(self, a, batched=False):
        shape = tuple(a.shape)
        if batched:
            ok = len(shape) >= 3 and shape[-2:] == self.shape
            expect = f"(..., {self.shape[0]}, {self.shape[1]})"
        else:
            ok = shape == self.shape
            expect = str(self.shape)
        if not ok:
            raise ValueError(
                f"plan compiled for shape {expect} got {shape}; plans "
                f"are per-shape — build another with plan(config, shape, "
                f"dtype)")
        if jnp.dtype(a.dtype) != jnp.dtype(self.dtype):
            raise ValueError(f"plan compiled for dtype "
                             f"{jnp.dtype(self.dtype).name} got "
                             f"{jnp.dtype(a.dtype).name}")

    def _batched(self, impl):
        if self.mode == "grouped":
            raise ValueError(
                "grouped (Algorithm 3) plans lay one matrix out over the "
                "('zolo', 'sep') mesh; batching is not supported — build "
                "a static/dynamic plan for batched inputs")

        def run(a):
            lead = a.shape[:-2]
            flat = a.reshape((-1,) + self.shape)
            out = jax.vmap(impl)(flat)
            return jax.tree.map(
                lambda t: t.reshape(lead + t.shape[1:]), out)

        return run

    def svd(self, a):
        """A = U diag(s) V^H (paper Alg. 2), s descending — compiled."""
        self._check(a)
        return self._executable(("svd",), self._svd_impl)(a)

    def polar(self, a, want_h: bool = True):
        """(q, h, info) with A ~= Q H — compiled."""
        self._check(a)
        want_h = bool(want_h)
        return self._executable(
            ("polar", want_h),
            lambda x: self._polar_impl(x, want_h=want_h))(a)

    def svd_verified(self, a):
        """``svd`` plus its in-graph health — compiled.

        Returns ``(u, s, vh, health)`` with ``health`` a
        :class:`repro.resilience.health.SolveHealth` of device scalars
        (all-finite flag, ``||UᵀU - I||_F / n``, the driver's converged
        flag, and the runtime conditioning estimate), computed inside
        the same executable as the solve — one extra Gram reduction,
        no extra trace.  Judge it with
        :func:`repro.resilience.health.judge_plan`.
        """
        self._check(a)
        return self._executable(("svd_verified",),
                                self._svd_verified_impl)(a)

    def svd_batched(self, a):
        """``svd`` vmapped over leading axes of (..., m, n) — compiled."""
        self._check(a, batched=True)
        return self._executable(("svd_batched",),
                                self._batched(self._svd_impl))(a)

    def svd_batched_verified(self, a):
        """``svd_verified`` vmapped over leading axes — compiled.

        Health leaves carry the leading batch axes, so a serving layer
        triages entries individually (``jax.tree.map(lambda t: t[i],
        health)``) instead of failing a whole batch for one bad entry.
        """
        self._check(a, batched=True)
        return self._executable(("svd_batched_verified",),
                                self._batched(self._svd_verified_impl))(a)

    def polar_batched(self, a, want_h: bool = True):
        """``polar`` vmapped over leading axes — compiled (the ZoloMuon
        per-parameter-kind path)."""
        self._check(a, batched=True)
        want_h = bool(want_h)
        return self._executable(
            ("polar_batched", want_h),
            self._batched(lambda x: self._polar_impl(x,
                                                     want_h=want_h)))(a)


def plan(config: SvdConfig, shape, dtype, mesh=None) -> SvdPlan:
    """Resolve ``config`` for (shape, dtype[, mesh]) into a cached plan.

    Identical (config, shape, dtype, mesh) return the *same* plan object,
    whose compiled executables are reused — the compile-once / run-many
    contract.  A cached plan is rebuilt only if its backend registration
    changed underneath it.
    """
    if not isinstance(config, SvdConfig):
        raise TypeError(f"plan() takes an SvdConfig, got {type(config)}")
    shape = tuple(int(s) for s in shape)
    if len(shape) != 2:
        raise ValueError(f"plan() takes the 2-D problem shape (m, n), "
                         f"got {shape}; batched inputs go through "
                         f"svd_batched/polar_batched on a 2-D plan")
    dtype = jnp.dtype(dtype)
    key = (config, shape, dtype, mesh)
    cached = _PLANS.get(key)
    if cached is not None and cached._is_current():
        _STATS["plan_hits"] += 1
        _PLANS.move_to_end(key)
        return cached
    _STATS["plan_misses"] += 1
    spec, eig_spec, res, backend_kwargs, eig_kwargs = _resolve(
        config, shape, dtype, mesh)
    built = SvdPlan(config=config, shape=shape, dtype=dtype, mesh=mesh,
                    resolution=res, _spec=spec, _eig_spec=eig_spec,
                    _backend_kwargs=backend_kwargs,
                    _eig_kwargs=eig_kwargs)
    _PLANS[key] = built
    _PLANS.move_to_end(key)
    _evict()
    return built


def flops_estimate(config: SvdConfig, shape, dtype,
                   mesh=None) -> Optional[float]:
    """Cost-model score of ``config`` at (shape, dtype) without executing.

    Resolves (and caches) the plan and returns its ``flops_estimate`` —
    the same per-backend ``flops_fn`` basis ``method="auto"`` ranks
    with.  This is the strategy hook higher-level planners build on:
    :func:`repro.spectral.plan_topk` prices its "dense" strategy with
    exactly this call, so a top-k plan's sketch-vs-dense decision and
    the solver's own backend selection share one cost-model contract.
    None when the resolved backend registers no cost model.
    """
    return plan(config, shape, dtype, mesh=mesh).flops_estimate


_CONFIG_CALL_FIELDS = (("r", int), ("l0", float), ("max_iters", int),
                       ("qr_iters", int), ("qr_mode", str))


def plan_for_call(shape, dtype, *, method: str, eig_method: str = "eigh",
                  nb: int = 32, mesh=None, kw=None):
    """Back-compat bridge for ``polar_svd`` / ``polar_decompose``.

    Maps a legacy call signature onto (cached plan, runtime kwargs): the
    recognized schedule-shaping kwargs move into the config — so a
    wrapper call and a direct ``plan()`` call with the same knobs share
    one cached plan — remaining hashable kwargs ride in ``config.extra``
    verbatim, and unhashable (array-valued) kwargs plus ``want_h``
    (per-call, not configuration) are returned for the caller to pass at
    execution time, outside the cache key.  ``scale="none"`` is pinned:
    legacy callers pre-scale their input, and the wrappers preserve
    those numerics exactly.
    """
    kw = dict(kw or {})
    cfg_kw = {}
    for name, cast in _CONFIG_CALL_FIELDS:
        if kw.get(name) is not None:
            cfg_kw[name] = cast(kw.pop(name))
    runtime = {}
    if "want_h" in kw:
        runtime["want_h"] = kw.pop("want_h")
    static = {}
    for k, v in kw.items():
        try:
            hash(v)
        except TypeError:
            runtime[k] = v
        else:
            static[k] = v
    cfg = SvdConfig(method=method, eig_method=eig_method, nb=nb,
                    scale="none", extra=tuple(sorted(static.items())),
                    **cfg_kw)
    return plan(cfg, shape, dtype, mesh=mesh), runtime
