"""Pallas TPU kernel: fused r-term polar update (paper Alg. 1 step 4d).

X2 = mhat * (X + sum_j a_j T_j)

is the combine step after the r shifted factorizations — a memory-bound
weighted reduction over r+1 arrays.  Fusing it avoids r separate
full-array read-modify-writes (2x-3x less HBM traffic for r = 2..3 than
naive chaining).

This is exactly the grouped combine of
:mod:`repro.kernels.grouped_combine` specialized to xw = 1 (every
single-address-space "group" carries X), so there is one kernel body:
this call delegates, keeping tile/dtype behavior in one place.
"""

from __future__ import annotations

from repro.kernels.grouped_combine import grouped_combine_kernel_call


def polar_update_kernel_call(x, t, a, mhat, *, bm: int = 256, bn: int = 256,
                             interpret: bool = False):
    """X2 = mhat * (X + sum_j a[j] * T[j]).

    x: (m, n); t: (r, m, n); a: (r,); mhat: scalar.  Output dtype follows
    x.  (xw = 1.0 is exact in f32: the shared kernel's extra multiply
    does not perturb the result.)
    """
    return grouped_combine_kernel_call(x, t, a, mhat, 1.0, bm=bm, bn=bn,
                                       interpret=interpret)
