"""Pallas TPU kernel: fused r-term polar update (paper Alg. 3 step 4d).

X2 = mhat * (X + sum_j a_j T_j)

is the combine step after the r groups' factorizations — the paper does it
with DGSUM2D; on one TPU slice it is a memory-bound weighted reduction over
r+1 arrays.  Fusing it avoids r separate full-array read-modify-writes
(2x-3x less HBM traffic for r = 2..3 than naive chaining).

T is stacked (r, m, n); the r loop is unrolled inside the kernel (r is
small and static: 2..8 per the paper's Table 1 policy).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _polar_update_kernel(x_ref, t_ref, a_ref, mhat_ref, out_ref, *, r: int):
    acc = x_ref[...].astype(jnp.float32)
    for j in range(r):
        acc += a_ref[j] * t_ref[j].astype(jnp.float32)
    out_ref[...] = (mhat_ref[0] * acc).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def polar_update_kernel_call(x, t, a, mhat, *, bm: int = 256, bn: int = 256,
                             interpret: bool = False):
    """X2 = mhat * (X + sum_j a[j] * T[j]).

    x: (m, n); t: (r, m, n); a: (r,); mhat: scalar.  Output dtype follows x.
    """
    m, n = x.shape
    r = t.shape[0]
    assert t.shape == (r, m, n)
    assert m % bm == 0 and n % bn == 0
    a_arr = jnp.asarray(a, jnp.float32)
    mhat_arr = jnp.asarray(mhat, jnp.float32).reshape(1)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_polar_update_kernel, r=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((r, bm, bn), lambda i, j: (0, i, j)),
            pl.BlockSpec((r,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, t, a_arr, mhat_arr)
