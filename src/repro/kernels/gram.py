"""Pallas TPU kernel: fused shifted Gram matrix  G = A^T A + c I.

This is the compute hot spot of Zolo-PD's Cholesky variant (Alg. 1 step 4d
and Alg. 3 step 4c): every iteration forms Z_j = X^T X + c_{2j-1} I.  The
fusion saves one full n^2 read-modify-write for the +cI (and the paper's
Gram-sharing optimization means this kernel runs once per iteration, not r
times).

Tiling: grid (n/bn, n/bn, m/bk); A is streamed twice through VMEM in
(bk, bn) tiles; the (bn, bn) output tile accumulates in f32 across the k
dimension (TPU ``arbitrary`` semantics on k make the revisits legal).  MXU
alignment: all tile dims are multiples of 128 by default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(a1_ref, a2_ref, c_ref, out_ref, *, n_k: int, bn: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a1 = a1_ref[...]
    a2 = a2_ref[...]
    out_ref[...] += jax.lax.dot_general(
        a1, a2, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(k == n_k - 1, i == j))
    def _shift_diag():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
        eye = (rows == cols).astype(out_ref.dtype)
        out_ref[...] += c_ref[0] * eye


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def gram_kernel_call(a, c, *, bn: int = 256, bk: int = 512,
                     interpret: bool = False):
    """G = A^T A + c I via pallas_call.  a: (m, n); c: scalar.

    Returns f32 (n, n).  m, n padded to tile multiples by the wrapper in
    ``ops.py``; this entry requires exact divisibility.
    """
    m, n = a.shape
    if n % bn != 0 or m % bk != 0:
        raise ValueError(
            f"gram_kernel_call needs tile-divisible shapes: got "
            f"({m}, {n}) with bn={bn}, bk={bk} — pad through "
            f"kernels.ops.gram instead")
    n_k = m // bk
    c_arr = jnp.asarray(c, jnp.float32).reshape(1)

    grid = (n // bn, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_gram_kernel, n_k=n_k, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(a, a, c_arr)
