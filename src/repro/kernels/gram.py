"""Pallas TPU kernel: fused shifted Gram matrix  G = A^T A + c I.

This is the compute hot spot of Zolo-PD's Cholesky variant (Alg. 1 step 4d
and Alg. 3 step 4c): every iteration forms Z_j = X^T X + c_{2j-1} I.  The
fusion saves one full n^2 read-modify-write for the +cI (and the paper's
Gram-sharing optimization means this kernel runs once per iteration, not r
times).

Tiling: grid (n/bn, n/bn, m/bk); A is streamed twice through VMEM in
(bk, bn) tiles; the (bn, bn) output tile accumulates in f32 across the k
dimension (TPU ``arbitrary`` semantics on k make the revisits legal).  MXU
alignment: all tile dims are multiples of 128 by default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Accumulation dtype of every dot in this kernel; sub-f32 inputs (bf16)
# are legal because the MXU widens to this before summing.  The
# conditioning envelope that pairs with it lives in
# ``repro.core.svd.PALLAS_KAPPA_ENVELOPE`` keyed by (input, accum) dtype.
GRAM_ACCUM_DTYPE = jnp.float32
GRAM_KAPPA_ENVELOPE = "repro.core.svd:PALLAS_KAPPA_ENVELOPE"

# In-kernel shift clamp: a *positive* Gram shift c is ridged up to at
# least SHIFT_RIDGE_FACTOR * eps(accum) * max diag(G).  At kappa >~ 1e4
# the odd Zolotarev coefficients underflow past the accumulated Gram's
# eps-level negative eigenvalues, Z = G + cI goes indefinite, and the
# downstream Cholesky emits NaN (ROADMAP 4a).  Ridging by an
# eps-of-the-accumulator multiple is below the Gram's own rounding error,
# so clean solves are unperturbed; c == 0 (unshifted Grams: CholeskyQR2's
# G2, the sigma_min estimate) is never touched.
SHIFT_RIDGE_FACTOR = 8.0


def _gram_kernel(a1_ref, a2_ref, c_ref, out_ref, *, n_k: int, bn: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a1 = a1_ref[...]
    a2 = a2_ref[...]
    out_ref[...] += jax.lax.dot_general(
        a1, a2, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(k == n_k - 1, i == j))
    def _shift_diag():
        rows = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
        eye = (rows == cols).astype(out_ref.dtype)
        c = c_ref[0]
        # shift clamp: ridge a positive shift against the accumulator's
        # eps so Z = G + cI stays definite (see SHIFT_RIDGE_FACTOR)
        diag_max = jnp.max(out_ref[...] * eye)
        floor = (SHIFT_RIDGE_FACTOR
                 * jnp.finfo(GRAM_ACCUM_DTYPE).eps
                 * jnp.maximum(diag_max, 0.0))
        c_eff = jnp.where(c > 0.0, jnp.maximum(c, floor), c)
        out_ref[...] += c_eff * eye


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def gram_kernel_call(a, c, *, bn: int = 256, bk: int = 512,
                     interpret: bool = False):
    """G = A^T A + c I via pallas_call.  a: (m, n); c: scalar.

    Returns f32 (n, n).  m, n padded to tile multiples by the wrapper in
    ``ops.py``; this entry requires exact divisibility.
    """
    m, n = a.shape
    if n % bn != 0 or m % bk != 0:
        raise ValueError(
            f"gram_kernel_call needs tile-divisible shapes: got "
            f"({m}, {n}) with bn={bn}, bk={bk} — pad through "
            f"kernels.ops.gram instead")
    n_k = m // bk
    c_arr = jnp.asarray(c, jnp.float32).reshape(1)

    grid = (n // bn, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_gram_kernel, n_k=n_k, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(a, a, c_arr)
