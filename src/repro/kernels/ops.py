"""Jit'd public wrappers around the Pallas kernels.

Handle padding to tile multiples, dtype policy, and the CPU fallback
(interpret=True executes the kernel body in Python for validation; real
deployments run the compiled TPU kernels).  ``use_pallas=False`` routes to
the jnp oracle — the dry-run path uses the oracle so XLA:TPU's own fusions
are what the roofline counts, while the Pallas kernels remain the
hand-tuned hot-spot option (benchmarks compare both).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.gram import gram_kernel_call
from repro.kernels.grouped_combine import grouped_combine_kernel_call
from repro.kernels.matmul import matmul_kernel_call
from repro.kernels.polar_update import polar_update_kernel_call


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, mult_rows, mult_cols):
    m, n = x.shape[-2:]
    pm = (-m) % mult_rows
    pn = (-n) % mult_cols
    if pm or pn:
        pad = [(0, 0)] * (x.ndim - 2) + [(0, pm), (0, pn)]
        x = jnp.pad(x, pad)
    return x, (m, n)


def _tile_align(dtype) -> int:
    """Minimum MXU lane-tile alignment for ``dtype``.

    TPU register tiles hold 32 bits per lane slot, so sub-f32 dtypes pack
    more elements per (8, 128) native tile: f32/f64 align at 128 lanes,
    bf16/f16 at 256, int8/fp8 at 512.  Using a flat 128 for bf16 made
    ``_pick_tile`` hand back 128-lane tiles the Mosaic lowering rejects,
    and the wrappers silently fell through to the jnp oracle."""
    itemsize = jnp.dtype(dtype).itemsize
    return 128 * max(1, 4 // itemsize)


def _pick_tile(dim: int, target: int, align: int = 128) -> int:
    """Largest align-multiple tile <= target that divides dim after
    align-padding.

    ``target`` is rounded down to an ``align`` multiple first: tile dims
    must keep MXU alignment, and a non-multiple target (now reachable via
    ``SvdConfig.extra`` tile knobs) would otherwise never divide the
    padded dim — the decrement loop walked past zero and never
    terminated."""
    if target < align:
        raise ValueError(f"tile target {target} < MXU alignment {align}")
    padded = dim + ((-dim) % align)
    t = min(target - target % align, padded)
    while padded % t:
        t -= align
    return max(t, align)


def gram(a, c=0.0, *, bn: int = 256, bk: int = 512, use_pallas: bool = True):
    """G = A^T A + c I with f32 accumulation."""
    if not use_pallas:
        return ref.gram_ref(a, c)
    m, n = a.shape
    align = _tile_align(a.dtype)
    bn = _pick_tile(n, bn, align)
    bk = _pick_tile(m, bk, align)
    a_p, _ = _pad_to(a, bk, bn)
    g = gram_kernel_call(a_p, c, bn=bn, bk=bk, interpret=_interpret())
    return g[:n, :n]


def matmul(a, b, alpha=1.0, *, bm: int = 256, bn: int = 256, bk: int = 512,
           use_pallas: bool = True):
    """C = alpha * A @ B with f32 accumulation."""
    if not use_pallas:
        return ref.matmul_ref(a, b, alpha)
    m, k = a.shape
    _, n = b.shape
    align = max(_tile_align(a.dtype), _tile_align(b.dtype))
    bm = _pick_tile(m, bm, align)
    bn = _pick_tile(n, bn, align)
    bk = _pick_tile(k, bk, align)
    a_p, _ = _pad_to(a, bm, bk)
    b_p, _ = _pad_to(b, bk, bn)
    c = matmul_kernel_call(a_p, b_p, alpha, bm=bm, bn=bn, bk=bk,
                           interpret=_interpret())
    return c[:m, :n]


def polar_update(x, t, a, mhat, *, bm: int = 256, bn: int = 256,
                 use_pallas: bool = True):
    """X2 = mhat * (X + sum_j a_j T_j)."""
    if not use_pallas:
        return ref.polar_update_ref(x, t, a, mhat)
    m, n = x.shape
    align = max(_tile_align(x.dtype), _tile_align(t.dtype))
    bm = _pick_tile(m, bm, align)
    bn = _pick_tile(n, bn, align)
    x_p, _ = _pad_to(x, bm, bn)
    t_p, _ = _pad_to(t, bm, bn)
    out = polar_update_kernel_call(x_p, t_p, a, mhat, bm=bm, bn=bn,
                                   interpret=_interpret())
    return out[:m, :n]


def grouped_combine(x, t, a, mhat, xw=1.0, *, bm: int = 256, bn: int = 256,
                    use_pallas=None):
    """Y = mhat * (xw * X + sum_j a_j T_j) — one group's pre-psum combine
    contribution (see :mod:`repro.kernels.grouped_combine`).

    ``psum(Y, "zolo")`` with ``xw`` one-hot over the groups yields the
    next Zolotarev iterate directly.  ``use_pallas=None`` (the default)
    compiles the kernel on TPU and uses the jnp oracle elsewhere — this
    op sits on the main grouped (Alg. 3) path, where CPU interpret mode
    would execute the kernel body in Python per device; pass
    ``use_pallas=True`` to force the kernel (interpret mode off-TPU, the
    parity-test path) or ``False`` to force the oracle.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return ref.grouped_combine_ref(x, t, a, mhat, xw)
    m, n = x.shape
    align = max(_tile_align(x.dtype), _tile_align(t.dtype))
    bm = _pick_tile(m, bm, align)
    bn = _pick_tile(n, bn, align)
    x_p, _ = _pad_to(x, bm, bn)
    t_p, _ = _pad_to(t, bm, bn)
    out = grouped_combine_kernel_call(x_p, t_p, a, mhat, xw, bm=bm, bn=bn,
                                      interpret=_interpret())
    return out[:m, :n]


def flash_attention(q, k, v, *, bq: int = 256, bk: int = 256,
                    use_pallas: bool = True):
    """Causal flash attention.  q/k/v: (b, s, h, d) (GQA pre-expanded).

    Pallas kernel with online-softmax VMEM state; oracle fallback via
    ``use_pallas=False``."""
    from repro.kernels.flash_attention import flash_attention_kernel_call

    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=True).astype(q.dtype)
    b, s, h, d = q.shape

    def pick_seq_tile(target: int) -> int:
        # largest divisor of s that is <= target and a multiple of 16
        # (the seq dim has no MXU 128-alignment requirement)
        for t in range(min(target, s), 15, -16):
            if s % t == 0 and t % 16 == 0:
                return t
        return s  # fall back: single tile

    bq = pick_seq_tile(bq)
    bk = pick_seq_tile(bk)
    qk = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kk = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vk = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    o = flash_attention_kernel_call(qk, kk, vk, bq=bq, bk=bk,
                                    interpret=_interpret())
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)
