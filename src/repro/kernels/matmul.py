"""Pallas TPU kernel: tiled matmul C = alpha * A @ B (f32 accumulate).

Used by the polar/SVD pipeline for the dense products that are not Gram
matrices: Q1 Q2^T (eq. 12), U = Q_p V (Alg. 2 step 3), and H formation.
Standard (i, j, k) tiling with output revisiting on the contraction axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# f32 accumulation for any input dtype (bf16 included); the paired
# conditioning envelope is ``repro.core.svd.PALLAS_KAPPA_ENVELOPE``.
MATMUL_ACCUM_DTYPE = jnp.float32
MATMUL_KAPPA_ENVELOPE = "repro.core.svd:PALLAS_KAPPA_ENVELOPE"


def _matmul_kernel(a_ref, b_ref, alpha_ref, out_ref, *, n_k: int):
    k = pl.program_id(2)  # i, j unused: output block fixed by (0, 1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _scale():
        out_ref[...] *= alpha_ref[0]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_kernel_call(a, b, alpha=1.0, *, bm: int = 256, bn: int = 256,
                       bk: int = 512, interpret: bool = False):
    """C = alpha * A @ B.  a: (m, k); b: (k, n) -> f32 (m, n)."""
    m, kk = a.shape
    k2, n = b.shape
    if kk != k2:
        raise ValueError(
            f"matmul_kernel_call: inner dims disagree ({kk} vs {k2})")
    if m % bm != 0 or n % bn != 0 or kk % bk != 0:
        raise ValueError(
            f"matmul_kernel_call needs tile-divisible shapes: got "
            f"({m}, {kk}) @ ({k2}, {n}) with bm={bm}, bn={bn}, bk={bk} "
            f"— pad through kernels.ops.matmul instead")
    n_k = kk // bk
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1)
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b, alpha_arr)
