"""Pallas TPU kernels for the paper's compute hot spots.

One module per kernel (``gram``, ``polar_update``, ``grouped_combine``,
``matmul``, ``flash_attention``) + jnp oracles in ``ref.py`` + the jit'd
public wrappers in ``ops.py`` (padding, tile selection, interpret-mode
fallback off-TPU).  The solver reaches these through the registered
``zolo_pallas`` backend (:mod:`repro.core.zolo_pallas`), which injects
``ops.gram`` / ``ops.polar_update`` into the shared Zolotarev driver via
its :class:`repro.core.zolo.ZoloOps` bundle, and through the grouped
(Algorithm 3) driver in :mod:`repro.dist.grouped`, whose per-group
combine contribution runs on ``ops.grouped_combine`` (fused with the
"zolo"-axis psum: the collective carries the next iterate).
"""
