"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""

from __future__ import annotations

import jax.numpy as jnp


def gram_ref(a, c):
    """G = A^T A + c I, f32."""
    n = a.shape[1]
    g = jnp.einsum("mk,mn->kn", a, a, preferred_element_type=jnp.float32)
    return g + jnp.asarray(c, jnp.float32) * jnp.eye(n, dtype=jnp.float32)


def matmul_ref(a, b, alpha=1.0):
    """C = alpha * A @ B, f32."""
    c = jnp.einsum("mk,kn->mn", a, b, preferred_element_type=jnp.float32)
    return jnp.asarray(alpha, jnp.float32) * c


def polar_update_ref(x, t, a, mhat):
    """X2 = mhat * (X + sum_j a_j T_j), dtype of x."""
    acc = x.astype(jnp.float32) + jnp.einsum(
        "j,jmn->mn", jnp.asarray(a, jnp.float32), t.astype(jnp.float32))
    return (jnp.asarray(mhat, jnp.float32) * acc).astype(x.dtype)


def grouped_combine_ref(x, t, a, mhat, xw=1.0):
    """Y = mhat * (xw * x + sum_j a_j T_j), dtype of x.

    Accumulates in f32-or-better (f64 inputs stay f64: off-TPU this
    oracle IS the grouped driver's combine, and the distributed parity
    tests run in f64)."""
    ct = jnp.promote_types(x.dtype, jnp.float32)
    acc = jnp.asarray(xw, ct) * x.astype(ct) + jnp.einsum(
        "j,jmn->mn", jnp.asarray(a, ct), t.astype(ct))
    return (jnp.asarray(mhat, ct) * acc).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, scale=None, window=None):
    """Reference causal (optionally sliding-window) attention.

    q: (b, sq, h, d); k, v: (b, skv, h, d).  Returns (b, sq, h, d) in f32.
    Query position i attends to key j iff j <= i + (skv - sq) and, with a
    window w, j > i + (skv - sq) - w.
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = kpos <= qpos
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
