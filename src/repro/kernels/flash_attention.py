"""Pallas TPU kernel: causal flash attention (serving/training hot spot).

Beyond-paper addition: the LM stack's chunked-softmax attention as an
explicit VMEM-tiled kernel.  Grid (batch*kv_heads*groups, q_blocks,
kv_blocks); the kv dimension is ``arbitrary`` (sequential) so the online
(max, sum, acc) state lives in VMEM scratch across kv steps.  Causality
is enforced by masking inside the diagonal block; fully-masked kv blocks
are skipped by the index map never visiting them (the grid's kv extent is
per-q-block via the mask, kept simple here: full extent + mask).

Validated in interpret mode against ``ref.flash_attention_ref``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Online-softmax state and both dots accumulate in f32 for any q/k/v
# dtype; conditioning envelope (not kappa-sensitive, listed for the
# kernel-accum-envelope lint): repro.core.svd.PALLAS_KAPPA_ENVELOPE.
FLASH_ACCUM_DTYPE = jnp.float32
FLASH_KAPPA_ENVELOPE = "repro.core.svd:PALLAS_KAPPA_ENVELOPE"


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, bq: int, bk: int, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # causal mask on absolute positions
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s = jnp.where(k_pos <= q_pos, s, -1e30)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention_kernel_call(q, k, v, *, bq: int = 256, bk: int = 256,
                                interpret: bool = False):
    """Causal attention.  q, k, v: (bh, s, d) with bh = batch*heads
    (GQA pre-expanded by the wrapper).  Returns (bh, s, d) in q.dtype."""
    bh, s, d = q.shape
    if s % bq != 0 or s % bk != 0:
        raise ValueError(
            f"flash_attention_kernel_call needs a tile-divisible "
            f"sequence: got s={s} with bq={bq}, bk={bk}")
    scale = 1.0 / math.sqrt(d)
    n_q = s // bq
    n_k = s // bk
    grid = (bh, n_q, n_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                          n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        # f32 VMEM scratch carrying the online-softmax state across the
        # kv-sequential grid dimension
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
