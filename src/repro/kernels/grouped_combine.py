"""Pallas TPU kernel: fused grouped combine (paper Alg. 3 step 4d + DGSUM2D).

Each Zolotarev group's contribution to the next iterate is

    Y_g = mhat * (xw_g * X + sum_j a_j T_j)

with ``xw_g`` = 1 on exactly one group and 0 elsewhere, so the "zolo"-axis
``psum`` of the Y_g *is* the updated iterate

    X2 = psum_zolo(Y_g) = mhat * (X + sum over all groups' terms)

and the replicated post-psum epilogue ``mhat * (X + t)`` of the old
grouped driver disappears: the weighted term combine is fused into the
pre-psum pass and the collective itself carries the result (the paper's
DGSUM2D directly produces the next iterate on every group).

T is stacked (r_local, m, n) — the group's local terms, row-sharded over
the "sep" axis exactly like X; in grouped (Alg. 3) execution r_local is 1.
The r loop is unrolled (r is small and static).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The combine accumulates in f32 whatever the iterate dtype; the paired
# conditioning envelope is ``repro.core.svd.PALLAS_KAPPA_ENVELOPE``.
COMBINE_ACCUM_DTYPE = jnp.float32
COMBINE_KAPPA_ENVELOPE = "repro.core.svd:PALLAS_KAPPA_ENVELOPE"


def _grouped_combine_kernel(x_ref, t_ref, a_ref, s_ref, out_ref, *, r: int):
    # s = [mhat, xw]: the epilogue scale and this group's X weight
    acc = s_ref[1] * x_ref[...].astype(jnp.float32)
    for j in range(r):
        acc += a_ref[j] * t_ref[j].astype(jnp.float32)
    out_ref[...] = (s_ref[0] * acc).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def grouped_combine_kernel_call(x, t, a, mhat, xw, *, bm: int = 256,
                                bn: int = 256, interpret: bool = False):
    """Y = mhat * (xw * X + sum_j a[j] * T[j]).

    x: (m, n); t: (r, m, n); a: (r,); mhat, xw: scalars (xw may be a
    traced per-group value, e.g. ``axis_index("zolo") == 0``).  Output
    dtype follows x.
    """
    m, n = x.shape
    r = t.shape[0]
    if t.shape != (r, m, n):
        raise ValueError(
            f"grouped_combine_kernel_call: terms shape {t.shape} does "
            f"not stack x's {(m, n)} over r={r}")
    if m % bm != 0 or n % bn != 0:
        raise ValueError(
            f"grouped_combine_kernel_call needs tile-divisible shapes: "
            f"got ({m}, {n}) with bm={bm}, bn={bn} — pad through "
            f"kernels.ops.grouped_combine instead")
    a_arr = jnp.asarray(a, jnp.float32)
    s_arr = jnp.stack([jnp.asarray(mhat, jnp.float32).reshape(()),
                       jnp.asarray(xw, jnp.float32).reshape(())])
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_grouped_combine_kernel, r=r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((r, bm, bn), lambda i, j: (0, i, j)),
            pl.BlockSpec((r,), lambda i, j: (0,)),
            pl.BlockSpec((2,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, t, a_arr, s_arr)
