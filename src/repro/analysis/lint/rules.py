"""Built-in lint rules — each one encodes a bug this repo already hit.

==================  =====================================================
rule                historical bug it encodes
==================  =====================================================
collective-axis     PR 4: psum/axis_index against an axis name that is
                    not bound by the surrounding mesh traces fine on one
                    device and deadlocks/miscomputes on a real slice;
                    ``check_rep=False`` without a written justification
                    hides replication-rule bugs (the double-psum class).
accum-dtype         PR 3: a Gram/einsum product without
                    ``preferred_element_type`` accumulates bf16/f16 on
                    TPU, and the downstream Cholesky/QR factors garbage.
plan-key-hygiene    PR 2/6: plan caches key on the config dataclass —
                    a mutable or unhashable config either explodes at
                    lookup or (worse) silently defeats the cache.
retrace-hazard      PR 6: ``float()``/``int()``/``np.*``/Python ``if``
                    on a traced value inside a jitted body either fails
                    at trace time or forces a retrace per call — the
                    serving path's zero-retrace guarantee dies.
bare-assert         PR 5: library ``assert`` vanishes under ``python
                    -O`` and reports no operand context; shape proofs
                    must fail loudly with real exceptions.
keyerror-dispatch   PR 3: registry dispatch through ``TABLE[name]``
                    surfaces an unactionable ``KeyError: 'zolo'``
                    instead of naming the known choices.
kernel-accum-       ROADMAP 4: a Pallas kernel that accepts sub-f32
envelope            operands but leaves an MXU product's accumulator
                    unpinned accumulates bf16 on TPU, and a kernel
                    module without a declared accumulator dtype and
                    envelope registration leaves the planner/health
                    judge nothing to gate its precision on.
==================  =====================================================

Heuristics are deliberately precision-first: variable-valued arguments
(e.g. the ``axis: str = "sep"`` parameters threaded through
``repro.dist.grouped_ops``) are not flagged — only literals the AST can
prove.  What a rule cannot prove it stays silent about; the jaxpr
auditor (:mod:`repro.analysis.jaxpr_audit`) covers the runtime side.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.lint.engine import FileContext, Finding, register_rule

# ---------------------------------------------------------------------------
# shared AST helpers


def _call_name(node: ast.Call) -> str:
    """Dotted name of a call target: ``jax.lax.psum`` -> ``jax.lax.psum``."""
    return _dotted(node.func)


def _dotted(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _str_consts(node: ast.AST) -> List[str]:
    """All string literals in an expression (tuples/lists flattened)."""
    out = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            out.append(sub.value)
    return out


def _kwarg(node: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _functions(tree: ast.AST) -> List[ast.FunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


# ---------------------------------------------------------------------------
# collective-axis


class CollectiveAxisRule:
    """psum/axis_index axis names must be declared somewhere in the module
    (mesh construction, PartitionSpec, or an ``axis=``-style parameter
    default); ``check_rep=False`` needs a justification comment that
    mentions ``check_rep``."""

    name = "collective-axis"
    doc = ("collective axis literals must match a declared mesh axis; "
           "check_rep=False requires a 'check_rep' justification comment")

    COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "all_gather",
                   "axis_index", "psum_scatter", "ppermute", "pshuffle",
                   "all_to_all"}
    SPEC_CALLS = {"P", "PartitionSpec", "NamedSharding"}
    AXIS_PARAMS = {"axis", "axis_name", "axis_names", "data_axis"}

    def declared_axes(self, ctx: FileContext) -> Set[str]:
        axes: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                callee = _call_name(node)
                tail = callee.rsplit(".", 1)[-1]
                if tail == "Mesh" or tail.endswith("_mesh") or tail in self.SPEC_CALLS:
                    for arg in list(node.args) + [kw.value for kw in node.keywords]:
                        axes.update(_str_consts(arg))
                kw = _kwarg(node, "axis_names")
                if kw is not None:
                    axes.update(_str_consts(kw))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                named = args.posonlyargs + args.args + args.kwonlyargs
                defaults = ([None] * (len(args.posonlyargs) + len(args.args)
                                      - len(args.defaults))
                            + list(args.defaults) + list(args.kw_defaults))
                for a, d in zip(named, defaults):
                    if a.arg in self.AXIS_PARAMS and d is not None:
                        axes.update(_str_consts(d))
            elif isinstance(node, ast.Assign):
                # module/function constants that look like axis tuples:
                #   AXES = ("zolo", "sep")
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and "axis" in tgt.id.lower():
                        axes.update(_str_consts(node.value))
        return axes

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        declared = self.declared_axes(ctx)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = _call_name(node)
            tail = callee.rsplit(".", 1)[-1]
            if tail in self.COLLECTIVES:
                axis_args: List[ast.expr] = []
                if tail == "axis_index":
                    axis_args += node.args[:1]
                else:
                    axis_args += node.args[1:2]
                for kwname in ("axis_name", "axis"):
                    kw = _kwarg(node, kwname)
                    if kw is not None:
                        axis_args.append(kw)
                for arg in axis_args:
                    for lit in _str_consts(arg):
                        if declared and lit not in declared:
                            yield ctx.finding(
                                node, self.name,
                                f"{tail}(..., {lit!r}): axis {lit!r} is not "
                                f"declared in this module (known: "
                                f"{sorted(declared)})")
                        elif not declared:
                            yield ctx.finding(
                                node, self.name,
                                f"{tail}(..., {lit!r}): no mesh axes are "
                                f"declared in this module at all")
            kw = _kwarg(node, "check_rep")
            if (kw is not None and isinstance(kw, ast.Constant)
                    and kw.value is False):
                near = ctx.comment_near(node.lineno)
                if "check_rep" not in near:
                    yield ctx.finding(
                        node, self.name,
                        "check_rep=False without a justification comment "
                        "mentioning 'check_rep' (replication-rule checking "
                        "caught the PR 4 double-psum class)")


# ---------------------------------------------------------------------------
# accum-dtype


class AccumDtypeRule:
    """Product ops feeding a factorization must pin their accumulator:
    ``einsum``/``matmul``/``dot``/``tensordot`` results that reach
    ``cholesky``/``qr``/``eigh``/``cholesky_qr2`` need
    ``preferred_element_type`` (or an explicit f32 promotion)."""

    name = "accum-dtype"
    doc = ("Gram/einsum accumulators feeding Cholesky/QR/eigh must carry "
           "preferred_element_type (bf16/f16 accumulation broke PR 3)")

    PRODUCTS = {"einsum", "matmul", "dot", "tensordot", "dot_general"}
    SINKS = {"cholesky", "qr", "eigh", "cholesky_qr2", "eig", "svd",
             "structured_qr_factor"}

    def _product_call(self, node: ast.AST) -> Optional[ast.Call]:
        if (isinstance(node, ast.Call)
                and _call_name(node).rsplit(".", 1)[-1] in self.PRODUCTS
                and _kwarg(node, "preferred_element_type") is None):
            return node
        return None

    def _names_in(self, node: ast.AST) -> Set[str]:
        return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        # nested defs are walked by their enclosing function too; flag
        # each product call once (outermost function wins)
        flagged: Set[int] = set()
        for fn in _functions(ctx.tree):
            yield from self._check_fn(ctx, fn, flagged)

    def _check_fn(self, ctx: FileContext, fn: ast.FunctionDef,
                  flagged: Set[int]):
        # 1. collect simple assignments name -> rhs (last write wins is
        #    fine for the fixpoint: we only need reachability).
        assigns: List[Tuple[str, ast.expr]] = []
        sink_args: List[ast.expr] = []
        body_nodes = list(ast.walk(fn))
        for node in body_nodes:
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name):
                    assigns.append((tgt.id, node.value))
                elif isinstance(tgt, ast.Tuple):
                    for el in tgt.elts:
                        if isinstance(el, ast.Name):
                            assigns.append((el.id, node.value))
            if isinstance(node, ast.Call):
                if _call_name(node).rsplit(".", 1)[-1] in self.SINKS:
                    sink_args.extend(node.args)
                    sink_args.extend(kw.value for kw in node.keywords)
        if not sink_args:
            return
        # 2. backward-reachable name set from the sink arguments.
        reach: Set[str] = set()
        for arg in sink_args:
            reach |= self._names_in(arg)
        for _ in range(len(assigns) + 1):
            grew = False
            for name, rhs in assigns:
                if name in reach:
                    new = self._names_in(rhs) - reach
                    if new:
                        reach |= new
                        grew = True
            if not grew:
                break
        # 3. flag unpinned product calls that feed the sink: either
        #    directly inside a sink argument, or assigned to a reachable
        #    name.

        def flag(call: ast.Call, how: str):
            if id(call) in flagged:
                return None
            flagged.add(id(call))
            op = _call_name(call).rsplit(".", 1)[-1]
            return ctx.finding(
                call, self.name,
                f"{op} result {how} a factorization in "
                f"{fn.name}() without preferred_element_type "
                f"(pin the accumulator or promote to f32 first)")

        for arg in sink_args:
            for sub in ast.walk(arg):
                call = self._product_call(sub)
                if call is not None:
                    f = flag(call, "feeds")
                    if f:
                        yield f
        for name, rhs in assigns:
            if name not in reach:
                continue
            for sub in ast.walk(rhs):
                call = self._product_call(sub)
                if call is not None:
                    f = flag(call, f"(via {name!r}) reaches")
                    if f:
                        yield f


# ---------------------------------------------------------------------------
# plan-key-hygiene


class PlanKeyHygieneRule:
    """Config-style dataclasses feed plan-cache keys: they must be
    ``frozen=True`` and must not annotate fields with unhashable or
    array types."""

    name = "plan-key-hygiene"
    doc = ("*Config/*Policy/*Key dataclasses feed cache keys: frozen=True "
           "required, no list/dict/set/ndarray-typed fields")

    SUFFIXES = ("Config", "Policy", "Key")
    UNHASHABLE = {"list", "List", "dict", "Dict", "set", "Set",
                  "bytearray", "ndarray", "Array"}

    def _dataclass_deco(self, cls: ast.ClassDef) -> Optional[ast.AST]:
        for deco in cls.decorator_list:
            target = deco.func if isinstance(deco, ast.Call) else deco
            if _dotted(target).rsplit(".", 1)[-1] == "dataclass":
                return deco
        return None

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if not node.name.endswith(self.SUFFIXES) or node.name.startswith("_"):
                continue
            deco = self._dataclass_deco(node)
            if deco is None:
                continue
            frozen = False
            if isinstance(deco, ast.Call):
                kw = _kwarg(deco, "frozen")
                frozen = (isinstance(kw, ast.Constant) and kw.value is True)
            if not frozen:
                yield ctx.finding(
                    node, self.name,
                    f"dataclass {node.name} looks like a cache-key config "
                    f"but is not frozen=True (mutable keys defeat the plan "
                    f"cache)")
            for stmt in node.body:
                if not isinstance(stmt, ast.AnnAssign):
                    continue
                ann_names = {_dotted(sub).rsplit(".", 1)[-1]
                             for sub in ast.walk(stmt.annotation)
                             if isinstance(sub, (ast.Name, ast.Attribute))}
                bad = ann_names & self.UNHASHABLE
                if bad:
                    field = stmt.target.id if isinstance(
                        stmt.target, ast.Name) else "?"
                    yield ctx.finding(
                        stmt, self.name,
                        f"{node.name}.{field}: {sorted(bad)[0]}-typed field "
                        f"is unhashable/array-valued — cache keys must hold "
                        f"hashable scalars/tuples")


# ---------------------------------------------------------------------------
# retrace-hazard


class RetraceHazardRule:
    """Inside jit/shard_map bodies and lax control-flow callbacks, flag
    host-side coercion of traced values: ``float()``/``int()``/``bool()``
    on parameter-derived expressions, ``np.*`` calls on them, and Python
    ``if`` statements testing a bare parameter."""

    name = "retrace-hazard"
    doc = ("float()/int()/np.*/Python-if on traced values inside jitted "
           "bodies concretize tracers or force per-call retraces")

    JIT_MARKERS = {"jit", "shard_map", "pmap", "smap"}
    LAX_CONSUMERS = {"while_loop", "fori_loop", "scan", "cond", "switch",
                     "custom_root"}
    COERCERS = {"float", "int", "bool"}
    STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding"}

    def _jitted_functions(self, ctx: FileContext) -> List[ast.FunctionDef]:
        out = []
        lax_fed: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                tail = _call_name(node).rsplit(".", 1)[-1]
                if tail in self.LAX_CONSUMERS:
                    for arg in node.args:
                        if isinstance(arg, ast.Name):
                            lax_fed.add(arg.id)
        for fn in _functions(ctx.tree):
            for deco in fn.decorator_list:
                names = {_dotted(s).rsplit(".", 1)[-1]
                         for s in ast.walk(deco)
                         if isinstance(s, (ast.Name, ast.Attribute))}
                if names & self.JIT_MARKERS:
                    out.append(fn)
                    break
            else:
                if fn.name in lax_fed:
                    out.append(fn)
        return out

    def _is_traced_expr(self, node: ast.AST, params: Set[str]) -> bool:
        """Does the expression mention a parameter as a bare Name (not
        through a static attribute like ``.shape``)?"""
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute) and sub.attr in self.STATIC_ATTRS:
                continue
            if isinstance(sub, ast.Name) and sub.id in params:
                # reject when this Name only appears under a static attr
                if not self._under_static_attr(node, sub):
                    return True
        return False

    def _under_static_attr(self, root: ast.AST, target: ast.Name) -> bool:
        for sub in ast.walk(root):
            if (isinstance(sub, ast.Attribute)
                    and sub.attr in self.STATIC_ATTRS):
                if any(s is target for s in ast.walk(sub.value)):
                    return True
        return False

    def _static_params(self, fn: ast.FunctionDef) -> Set[str]:
        """Names bound statically by the jit decorator
        (``static_argnames=(...)``) — not tracers."""
        out: Set[str] = set()
        for deco in fn.decorator_list:
            for sub in ast.walk(deco):
                if (isinstance(sub, ast.keyword)
                        and sub.arg == "static_argnames"):
                    out.update(_str_consts(sub.value))
        return out

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for fn in self._jitted_functions(ctx):
            params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                      + fn.args.kwonlyargs)}
            params -= self._static_params(fn)
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    callee = _call_name(node)
                    tail = callee.rsplit(".", 1)[-1]
                    if (callee in self.COERCERS and node.args
                            and self._is_traced_expr(node.args[0], params)):
                        yield ctx.finding(
                            node, self.name,
                            f"{callee}() on a traced value inside jitted "
                            f"{fn.name}() concretizes the tracer")
                    if (callee.startswith("np.") or callee.startswith("numpy.")) \
                            and node.args \
                            and self._is_traced_expr(node.args[0], params):
                        yield ctx.finding(
                            node, self.name,
                            f"{callee}() inside jitted {fn.name}() pulls a "
                            f"traced value to host numpy")
                    del tail
                elif isinstance(node, ast.If):
                    if self._is_traced_expr(node.test, params):
                        yield ctx.finding(
                            node, self.name,
                            f"Python `if` on a traced value inside jitted "
                            f"{fn.name}() branches at trace time (retrace "
                            f"per distinct value); use jnp.where/lax.cond")


# ---------------------------------------------------------------------------
# bare-assert


class BareAssertRule:
    """No ``assert`` in library code: it disappears under ``python -O``
    and carries no operand context.  Raise a real exception."""

    name = "bare-assert"
    doc = ("library asserts vanish under -O and hide operands; raise "
           "ValueError/AssertionError explicitly")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assert):
                yield ctx.finding(
                    node, self.name,
                    "bare assert in library code (stripped by -O); "
                    "use `if ...: raise`")


# ---------------------------------------------------------------------------
# keyerror-dispatch


class KeyErrorDispatchRule:
    """Dict dispatch on user input must fail loud: ``TABLE[name]`` where
    ``name`` is a function parameter and the function never membership-
    checks it raises a bare ``KeyError`` that names no alternatives."""

    name = "keyerror-dispatch"
    doc = ("dict dispatch on a parameter without a membership check "
           "raises an unactionable bare KeyError")

    def _guarded_names(self, fn: ast.FunctionDef) -> Set[str]:
        """Parameters that are membership-tested or .get()-dispatched
        somewhere in the function."""
        guarded: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Compare):
                ops = node.ops
                if any(isinstance(op, (ast.In, ast.NotIn)) for op in ops):
                    for sub in ast.walk(node.left):
                        if isinstance(sub, ast.Name):
                            guarded.add(sub.id)
            if isinstance(node, ast.Call):
                tail = _call_name(node).rsplit(".", 1)[-1]
                if tail == "get" and node.args:
                    for sub in ast.walk(node.args[0]):
                        if isinstance(sub, ast.Name):
                            guarded.add(sub.id)
            if isinstance(node, ast.Try):
                for handler in node.handlers:
                    htype = handler.type
                    names = {_dotted(s) for s in ast.walk(htype)} if htype else set()
                    if "KeyError" in names or htype is None:
                        # anything subscripted inside the try is guarded
                        for sub in ast.walk(node):
                            if isinstance(sub, ast.Subscript):
                                for s2 in ast.walk(sub.slice):
                                    if isinstance(s2, ast.Name):
                                        guarded.add(s2.id)
        return guarded

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        # dict-literal module/class-level tables by name
        tables: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Dict):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        tables.add(tgt.id)
        if not tables:
            return
        for fn in _functions(ctx.tree):
            params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                      + fn.args.kwonlyargs)}
            guarded = self._guarded_names(fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Subscript):
                    continue
                if not (isinstance(node.value, ast.Name)
                        and node.value.id in tables):
                    continue
                idx = node.slice
                if (isinstance(idx, ast.Name) and idx.id in params
                        and idx.id not in guarded):
                    yield ctx.finding(
                        node, self.name,
                        f"{node.value.id}[{idx.id}] dispatches on a "
                        f"parameter without a membership check — a typo "
                        f"raises bare KeyError naming no valid choices")


# ---------------------------------------------------------------------------
# kernel-accum-envelope


class KernelAccumEnvelopeRule:
    """Pallas kernel bodies must pin accumulation and declare an envelope.

    A kernel function is recognized structurally: two or more ``*_ref``
    parameters (pallas_call hands operands and outputs over as Refs).
    Such kernels may be handed sub-f32 operands (the bf16 envelope
    work), so two contracts apply:

    * every MXU product inside the body (``dot``/``dot_general``/
      ``einsum``/``matmul``) must pin ``preferred_element_type`` — an
      unpinned product accumulates in the operand dtype on TPU, which
      for bf16 inputs silently loses the f32 accumulation the envelope
      table was measured under;
    * the defining module must bind a module-level accumulator-dtype
      constant (a name containing ``ACCUM_DTYPE``) and an envelope
      registration pointer (a name containing ``ENVELOPE``), so the
      recorded precision contract is discoverable next to the kernel it
      governs rather than only in the planner.
    """

    name = "kernel-accum-envelope"
    doc = ("Pallas kernels taking sub-f32-capable Ref operands must pin "
           "preferred_element_type on MXU products and their module must "
           "declare *_ACCUM_DTYPE and an *ENVELOPE registration")

    PRODUCTS = {"dot", "dot_general", "einsum", "matmul"}

    def _kernel_fns(self, ctx: FileContext) -> List[ast.FunctionDef]:
        out = []
        for fn in _functions(ctx.tree):
            params = [a.arg for a in (fn.args.posonlyargs + fn.args.args
                                      + fn.args.kwonlyargs)]
            if sum(1 for p in params if p.endswith("_ref")) >= 2:
                out.append(fn)
        return out

    def _module_binds(self, ctx: FileContext, fragment: str) -> bool:
        for node in ctx.tree.body:  # module top level only
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign):
                targets = [node.target]
            for tgt in targets:
                if isinstance(tgt, ast.Name) and fragment in tgt.id:
                    return True
        return False

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        kernels = self._kernel_fns(ctx)
        if not kernels:
            return
        for fn in kernels:
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                tail = _call_name(node).rsplit(".", 1)[-1]
                if tail in self.PRODUCTS \
                        and _kwarg(node, "preferred_element_type") is None:
                    yield ctx.finding(
                        node, self.name,
                        f"{tail} inside kernel {fn.name}() without "
                        f"preferred_element_type: sub-f32 operands would "
                        f"accumulate in their own dtype, off the envelope "
                        f"the kernel was measured under")
        if not self._module_binds(ctx, "ACCUM_DTYPE"):
            yield ctx.finding(
                kernels[0], self.name,
                "kernel module declares no *_ACCUM_DTYPE constant: the "
                "accumulator precision the envelope was measured under "
                "must be stated next to the kernel")
        if not self._module_binds(ctx, "ENVELOPE"):
            yield ctx.finding(
                kernels[0], self.name,
                "kernel module declares no *ENVELOPE registration "
                "pointer: the planner/health judge gate sub-f32 use on "
                "a recorded kappa envelope — name where it lives")


register_rule(CollectiveAxisRule())
register_rule(AccumDtypeRule())
register_rule(PlanKeyHygieneRule())
register_rule(RetraceHazardRule())
register_rule(BareAssertRule())
register_rule(KeyErrorDispatchRule())
register_rule(KernelAccumEnvelopeRule())
