"""AST lint layer: engine + built-in rules (stdlib-only, no jax)."""

from repro.analysis.lint.engine import (  # noqa: F401
    FileContext,
    Finding,
    LintResult,
    Rule,
    all_rules,
    register_rule,
    run_lint,
)
