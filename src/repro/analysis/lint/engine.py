"""AST lint engine for the repo's hand-learned invariants.

Every hard bug in this codebase's history was an *invariant* violation,
not a logic error: bf16 entering QR unpromoted (PR 3), the ``gram_local``
double-psum (PR 4), silent ``assert``-guarded kernels, retraces from
Python branching on tracers.  This engine machine-checks those contracts
the way ScaLAPACK's descriptor discipline does structurally for the
paper's implementation (arXiv:1806.06204 §4).

Design:

* a :class:`Rule` is any object with ``name``, ``doc`` and
  ``check(ctx) -> Iterable[Finding]``; rules register through
  :func:`register_rule` (same idiom as ``repro.core.registry``);
* one :class:`FileContext` per file carries the parsed AST, source
  lines, and the comment map rules use for justification tags;
* suppressions are per-line comments —
  ``# repro-lint: disable=<rule>[,<rule>] -- why`` on the flagged line
  or the line above;
* a committed JSON baseline lets genuinely-accepted findings ride
  without exempting new code: fresh findings fail, baselined ones
  report as such, and fixed baseline entries are flagged stale so the
  file shrinks monotonically.

The engine is stdlib-only (``ast`` + ``tokenize``): it must run in the
bare-install CI job and never import jax.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import tokenize
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Protocol, Sequence

SUPPRESS_TAG = "repro-lint: disable="


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    path: str
    line: int
    col: int
    rule: str
    message: str
    snippet: str = ""  # stripped source line, for fingerprint identity

    def fingerprint(self) -> str:
        """Line-independent identity used by the baseline: the same
        violation must not re-fail just because code above it moved,
        but a *new* violation with the same message elsewhere in the
        file must not ride an old entry — the source text itself is the
        tiebreaker.  (Byte-identical duplicate violations in one file
        share an identity; a baseline entry then covers them all.)"""
        return f"{self.path}::{self.rule}::{self.message}::{self.snippet}"

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class Rule(Protocol):
    """Pluggable rule protocol: stateless check over one file."""

    name: str
    doc: str

    def check(self, ctx: "FileContext") -> Iterable[Finding]:
        ...


_RULES: Dict[str, Rule] = {}


def register_rule(rule: Rule) -> Rule:
    """Register ``rule`` under ``rule.name`` (fail loud on collisions)."""
    if rule.name in _RULES:
        raise ValueError(f"duplicate lint rule {rule.name!r}")
    _RULES[rule.name] = rule
    return rule


def all_rules() -> Dict[str, Rule]:
    # Import for side effect: the built-in rules self-register.
    from repro.analysis.lint import rules as _rules  # noqa: F401

    return dict(_RULES)


def resolve_rules(names: Optional[Sequence[str]] = None) -> List[Rule]:
    table = all_rules()
    if names is None:
        return [table[k] for k in sorted(table)]
    missing = sorted(set(names) - set(table))
    if missing:
        raise ValueError(
            f"unknown lint rule(s) {missing}; known: {sorted(table)}")
    return [table[k] for k in sorted(set(names))]


class FileContext:
    """Parsed view of one source file handed to every rule."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.comments = self._comment_map(source)

    @staticmethod
    def _comment_map(source: str) -> Dict[int, str]:
        out: Dict[int, str] = {}
        try:
            toks = tokenize.generate_tokens(io.StringIO(source).readline)
            for tok in toks:
                if tok.type == tokenize.COMMENT:
                    out[tok.start[0]] = tok.string
        except tokenize.TokenError:
            pass
        return out

    def comment_near(self, line: int, *, lookback: int = 6) -> str:
        """Concatenated comment text on ``line`` and up to ``lookback``
        contiguous comment/blank lines above it — the justification
        window rules search for tags like ``check_rep``."""
        parts = []
        if line in self.comments:
            parts.append(self.comments[line])
        cur = line - 1
        seen = 0
        while cur > 0 and seen < lookback:
            if cur in self.comments:
                parts.append(self.comments[cur])
            elif cur <= len(self.lines) and self.lines[cur - 1].strip():
                break  # non-comment code line ends the window
            cur -= 1
            seen += 1
        return "\n".join(parts)

    def suppressed(self, line: int, rule: str) -> bool:
        for cand in (line, line - 1):
            text = self.comments.get(cand, "")
            if SUPPRESS_TAG not in text:
                continue
            spec = text.split(SUPPRESS_TAG, 1)[1]
            spec = spec.split("--", 1)[0]
            names = {s.strip() for s in spec.replace(";", ",").split(",")}
            if rule in names or "all" in names:
                return True
        return False

    def finding(self, node: ast.AST, rule: str, message: str) -> Finding:
        line = getattr(node, "lineno", 0)
        snippet = (self.lines[line - 1].strip()
                   if 0 < line <= len(self.lines) else "")
        return Finding(
            path=self.path,
            line=line,
            col=getattr(node, "col_offset", 0),
            rule=rule,
            message=message,
            snippet=snippet,
        )


@dataclasses.dataclass
class LintResult:
    """Outcome of one lint run, split for CLI/CI consumption."""

    findings: List[Finding]          # new (non-baselined, unsuppressed)
    baselined: List[Finding]         # matched a committed baseline entry
    suppressed: int                  # silenced by inline disable comments
    stale_baseline: List[str]        # baseline fingerprints no longer seen
    files: int
    errors: List[str]                # unparseable files

    @property
    def ok(self) -> bool:
        return not self.findings and not self.errors


def iter_python_files(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    # de-dup while keeping order
    seen = set()
    uniq = []
    for p in out:
        if p not in seen:
            seen.add(p)
            uniq.append(p)
    return uniq


def load_baseline(path: Optional[str]) -> List[str]:
    if not path or not Path(path).exists():
        return []
    data = json.loads(Path(path).read_text())
    if isinstance(data, dict):
        data = data.get("fingerprints", [])
    if not isinstance(data, list):
        raise ValueError(f"baseline {path}: expected a JSON list")
    return [str(x) for x in data]


def write_baseline(path: str, findings: Sequence[Finding]) -> None:
    fps = sorted({f.fingerprint() for f in findings})
    Path(path).write_text(json.dumps(fps, indent=2) + "\n")


def run_lint(
    paths: Sequence[str],
    *,
    rules: Optional[Sequence[str]] = None,
    baseline: Optional[str] = None,
    source_loader: Optional[Callable[[Path], str]] = None,
) -> LintResult:
    """Lint every ``*.py`` under ``paths`` with the selected rules."""
    active = resolve_rules(rules)
    base_fps = set(load_baseline(baseline))
    new: List[Finding] = []
    known: List[Finding] = []
    errors: List[str] = []
    suppressed = 0
    seen_fps = set()
    files = iter_python_files(paths)
    for file in files:
        try:
            src = source_loader(file) if source_loader else file.read_text()
            ctx = FileContext(str(file), src)
        except (SyntaxError, UnicodeDecodeError) as e:
            errors.append(f"{file}: {e}")
            continue
        for rule in active:
            for f in rule.check(ctx):
                if ctx.suppressed(f.line, f.rule):
                    suppressed += 1
                    continue
                seen_fps.add(f.fingerprint())
                if f.fingerprint() in base_fps:
                    known.append(f)
                else:
                    new.append(f)
    stale = sorted(base_fps - seen_fps)
    new.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    known.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return LintResult(
        findings=new,
        baselined=known,
        suppressed=suppressed,
        stale_baseline=stale,
        files=len(files),
        errors=errors,
    )
