"""CLI: ``python -m repro.analysis [paths...] [options]``.

Exit codes: 0 clean (or baseline-covered), 1 new findings or parse
errors, 2 usage errors.  ``--format=json`` emits a machine-readable
report for the CI ``repro-lint`` step.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.lint.engine import (
    all_rules,
    run_lint,
    write_baseline,
)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-invariant linter (see src/repro/analysis/README.md)")
    p.add_argument("paths", nargs="*", default=["src/repro"],
                   help="files or directories to lint (default: src/repro)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule subset (default: all)")
    p.add_argument("--baseline", default=None,
                   help="JSON baseline of accepted finding fingerprints")
    p.add_argument("--write-baseline", action="store_true",
                   help="write current findings to --baseline and exit 0")
    p.add_argument("--list-rules", action="store_true")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for name, rule in sorted(all_rules().items()):
            print(f"{name}: {rule.doc}")
        return 0
    rules = args.rules.split(",") if args.rules else None
    try:
        result = run_lint(args.paths or ["src/repro"], rules=rules,
                          baseline=args.baseline)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        if not args.baseline:
            print("error: --write-baseline needs --baseline", file=sys.stderr)
            return 2
        write_baseline(args.baseline,
                       result.findings + result.baselined)
        print(f"wrote {len(result.findings) + len(result.baselined)} "
              f"fingerprint(s) to {args.baseline}")
        return 0

    if args.format == "json":
        print(json.dumps({
            "files": result.files,
            "findings": [f.to_json() for f in result.findings],
            "baselined": [f.to_json() for f in result.baselined],
            "suppressed": result.suppressed,
            "stale_baseline": result.stale_baseline,
            "errors": result.errors,
            "ok": result.ok,
        }, indent=2))
    else:
        for f in result.findings:
            print(f.render())
        for f in result.baselined:
            print(f"{f.render()} [baselined]")
        for e in result.errors:
            print(f"parse error: {e}", file=sys.stderr)
        for fp in result.stale_baseline:
            print(f"stale baseline entry (fixed? regenerate): {fp}",
                  file=sys.stderr)
        print(f"{result.files} file(s): {len(result.findings)} new, "
              f"{len(result.baselined)} baselined, "
              f"{result.suppressed} suppressed")
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
