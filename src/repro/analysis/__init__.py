"""Static + trace-level invariant checks for the solver/serve/spectral
stack.

Two complementary layers:

* :mod:`repro.analysis.lint` — a stdlib-only AST linter
  (``python -m repro.analysis src/repro``) encoding the repo's
  hand-learned invariants as ~6 precision-first rules (see
  ``analysis/README.md`` for the catalog, each rule named with the
  historical bug it guards against).
* :mod:`repro.analysis.jaxpr_audit` — lowers a plan's traceable impls
  and walks the jaxpr: psum count/axes per grouped iteration, f64
  discipline under ``compute_dtype``, no host callbacks.  Surfaced as
  ``SvdPlan.audit()`` / ``TopKPlan.audit()`` and the
  ``REPRO_AUDIT_PLANS=1`` pytest fixture.

The lint layer never imports jax (it runs in the bare CI job); import
the audit layer explicitly where a live plan exists.
"""

from repro.analysis.lint.engine import (  # noqa: F401
    FileContext,
    Finding,
    LintResult,
    Rule,
    all_rules,
    load_baseline,
    register_rule,
    run_lint,
    write_baseline,
)

__all__ = [
    "FileContext",
    "Finding",
    "LintResult",
    "Rule",
    "all_rules",
    "load_baseline",
    "register_rule",
    "run_lint",
    "write_baseline",
]
