"""Jaxpr-level plan auditor — the runtime complement of the AST linter.

The linter proves what source text can prove; this module proves what
only the *traced graph* can: that a plan's compiled callables contain
exactly the collectives the (r, sep) algorithm calls for, no f64
compute in an f32-compute plan, and no host callbacks.

The psum-count contract is the PR 4 bug class made executable.  One
grouped Zolotarev iteration owes the mesh exactly:

* **one "sep" psum per distributed Gram** — ``sep_reduce_ops`` reduces
  the partial (m/sep, n) row-block product once; the CholeskyQR2 term
  does it twice (X-Gram + Q1-Gram) and its Q2-Gram must stay *local*
  (``gram_local``).  A second reduction there double-counts the Gram —
  silently wrong on a real slice, invisible on one device.
* **one "zolo" psum per iteration** — the fused weighted combine that
  *is* the next iterate.

So a static plan with schedule length I (QR-seeded for the first
``qr_iters`` iterations) owes ``sep``: ``qr_iters * cost(qr_mode) +
(I - qr_iters)`` and ``zolo``: ``I``, where cost is {householder: 0,
cholqr2: 2, chol: 1}; the dynamic driver adds its in-graph sigma_min
Gram, the peeled first iteration's compiled branches, and two
residual-norm reductions outside plus three inside the while body.
:func:`expected_grouped_psums` encodes the model,
:func:`audit_plan` checks a live plan against it, and
``SvdPlan.audit()`` / ``TopKPlan.audit()`` expose it on the plan
objects themselves.  Module-level counters feed
``SvdService.stats()["plan_audits"]``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import jax

__all__ = [
    "AuditError",
    "AuditReport",
    "audit_callable",
    "audit_plan",
    "audit_all_plans",
    "audit_stats",
    "expected_grouped_psums",
    "iter_eqns",
]

# every shard_map spelling of an all-reduce; the rep-checker rewrites
# psum -> psum2 under check_rep=True, newer jax uses psum_invariant
PSUM_PRIMS = {"psum", "psum2", "psum_invariant"}
COLLECTIVE_PRIMS = PSUM_PRIMS | {
    "pmax", "pmin", "ppermute", "all_gather", "all_to_all",
    "reduce_scatter", "psum_scatter", "axis_index",
}
# f64 outputs of these primitives are *compute* in a wide dtype (the
# casts/transposes framing an f32-compute plan's f64 I/O are fine)
WIDE_COMPUTE_PRIMS = {
    "dot_general", "cholesky", "triangular_solve", "eigh", "eig", "qr",
    "lu", "svd", "householder_product", "integer_pow", "erf_inv",
    "pallas_call", "add", "sub", "mul", "div", "sqrt", "rsqrt", "exp",
    "log", "reduce_sum", "reduce_max", "reduce_min",
}
# one distributed-Gram "sep" psum per shared-Gram Cholesky term, two for
# the CholeskyQR2 term (X-Gram + Q1-Gram; the Q2-Gram is gram_local and
# owes NO reduction), none for structured Householder QR
MODE_SEP_PSUMS = {"chol": 1, "cholqr2": 2, "householder": 0}

_STATS = {"audited": 0, "passed": 0, "failed": 0}


def audit_stats() -> Dict[str, int]:
    """Monotonic audit counters (consumed by ``SvdService.stats()``)."""
    return dict(_STATS)


def reset_audit_stats() -> None:
    for k in _STATS:
        _STATS[k] = 0


class AuditError(RuntimeError):
    """A plan's traced graph violates a structural invariant."""

    def __init__(self, report: "AuditReport"):
        self.report = report
        lines = "\n  ".join(report.violations)
        super().__init__(
            f"plan audit failed for {report.entry}:\n  {lines}")


@dataclasses.dataclass
class AuditReport:
    """What one lowering revealed."""

    entry: str
    psum_counts: Dict[str, int]
    axis_names: Tuple[str, ...]       # every collective axis seen
    wide_compute: int                 # f64/c128 compute eqns found
    callbacks: Tuple[str, ...]
    checks: List[str]
    violations: List[str]

    @property
    def ok(self) -> bool:
        return not self.violations


def iter_eqns(jaxpr) -> Iterable[Any]:
    """Every eqn of ``jaxpr`` and all nested sub-jaxprs (pjit bodies,
    while/cond/scan branches, shard_map bodies, pallas kernels)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            items = val if isinstance(val, (list, tuple)) else (val,)
            for item in items:
                inner = getattr(item, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield from iter_eqns(inner)
                elif hasattr(item, "eqns"):
                    yield from iter_eqns(item)


def _collective_axes(eqn) -> Tuple[str, ...]:
    if eqn.primitive.name not in COLLECTIVE_PRIMS:
        return ()
    for key in ("axes", "axis_name", "axis_names"):
        val = eqn.params.get(key)
        if val is None:
            continue
        if isinstance(val, str):
            return (val,)
        return tuple(a for a in val if isinstance(a, str))
    return ()


def _is_wide(aval) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and dtype in ("float64", "complex128")


def audit_callable(
    fn,
    args: Sequence[Any],
    *,
    entry: str = "callable",
    mesh_axes: Sequence[str] = (),
    expect_psums: Optional[Dict[str, int]] = None,
    allow_collectives: bool = True,
    forbid_wide_compute: bool = False,
    raise_on_fail: bool = True,
) -> AuditReport:
    """Trace ``fn(*args)`` and walk the jaxpr for invariant violations.

    ``args`` are abstract (``jax.ShapeDtypeStruct``) or concrete inputs.
    ``mesh_axes`` is the set of legally-bound collective axis names;
    ``expect_psums`` the exact per-axis all-reduce budget (None skips the
    count check); ``allow_collectives=False`` asserts a collective-free
    graph (the non-grouped contract); ``forbid_wide_compute`` rejects
    f64/c128 arithmetic (the compute_dtype<=f32 contract).
    """
    closed = jax.make_jaxpr(fn)(*args)
    counts: Dict[str, int] = {}
    seen_axes: List[str] = []
    callbacks: List[str] = []
    wide = 0
    violations: List[str] = []
    checks: List[str] = []
    mesh_axes = tuple(mesh_axes)

    for eqn in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        axes = _collective_axes(eqn)
        if name in PSUM_PRIMS:
            for ax in axes:
                counts[ax] = counts.get(ax, 0) + 1
        if axes:
            for ax in axes:
                if ax not in seen_axes:
                    seen_axes.append(ax)
                if ax not in mesh_axes:
                    violations.append(
                        f"{name} over axis {ax!r} which is not bound by "
                        f"the plan's mesh (axes: {list(mesh_axes)})")
        elif name in COLLECTIVE_PRIMS and not allow_collectives:
            violations.append(f"collective {name} in a non-grouped graph")
        if "callback" in name or name == "outside_call":
            callbacks.append(name)
            violations.append(
                f"host callback primitive {name!r} in the compiled path "
                f"(breaks async dispatch and device-only serving)")
        if forbid_wide_compute and name in WIDE_COMPUTE_PRIMS:
            if any(_is_wide(v.aval) for v in eqn.outvars):
                wide += 1

    if seen_axes and not allow_collectives:
        violations.append(
            f"collectives over {seen_axes} in a graph that owes none")
    checks.append("collective-axis-validity")
    checks.append("no-host-callbacks")

    if forbid_wide_compute:
        checks.append("no-f64-compute")
        if wide:
            violations.append(
                f"{wide} f64/c128 compute eqn(s) in an f32-compute plan "
                f"(the compute_dtype cast is leaking)")

    if expect_psums is not None:
        checks.append("psum-count")
        for ax, want in expect_psums.items():
            got = counts.get(ax, 0)
            if got != want:
                hint = ("a Gram is reduced twice — the gram_local "
                        "double-psum class" if got > want
                        else "a reduction is missing — a partial Gram "
                        "or combine never left its shard")
                violations.append(
                    f"expected {want} {ax!r}-axis psum(s), found {got} "
                    f"({hint})")
        for ax in counts:
            if ax not in expect_psums:
                violations.append(
                    f"unbudgeted psum axis {ax!r} ({counts[ax]} eqn(s))")

    report = AuditReport(
        entry=entry,
        psum_counts=counts,
        axis_names=tuple(seen_axes),
        wide_compute=wide,
        callbacks=tuple(callbacks),
        checks=checks,
        violations=violations,
    )
    _STATS["audited"] += 1
    _STATS["passed" if report.ok else "failed"] += 1
    if raise_on_fail and not report.ok:
        raise AuditError(report)
    return report


def expected_grouped_psums(
    method: str,
    backend_kwargs: Dict[str, Any],
    *,
    sep: int = 1,
) -> Optional[Dict[str, int]]:
    """Per-axis all-reduce budget of one grouped plan's whole graph, or
    None when ``method`` is not a modelled grouped backend (the audit
    then still checks axis validity, just not counts).

    Counts are *static over the lowered jaxpr* — every compiled branch
    of the dynamic driver's peeled first iteration contributes, whether
    or not it executes.
    """
    if method == "zolo_grouped":
        sched = backend_kwargs.get("schedule") or ()
        iters = len(sched)
        if not iters:
            return None
        qr_mode = backend_kwargs.get("qr_mode", "cholqr2")
        qr_iters = min(int(backend_kwargs.get("qr_iters", 1)), iters)
        return {
            "sep": qr_iters * MODE_SEP_PSUMS[qr_mode]
            + (iters - qr_iters) * MODE_SEP_PSUMS["chol"],
            "zolo": iters,
        }
    if method == "zolo_grouped_dynamic":
        # in-graph sigma_min bound (skipped when the plan pinned l)
        est = 0 if "l" in backend_kwargs else 1
        first_mode = backend_kwargs.get("first_mode", "auto")
        if first_mode == "auto":
            # three compiled branches; structured Householder QR is only
            # row-distributable at sep == 1, else the extreme-regime
            # branch substitutes shifted CholeskyQR2
            hh = ("householder" if sep == 1 else "cholqr2")
            first_sep = (MODE_SEP_PSUMS[hh] + MODE_SEP_PSUMS["cholqr2"]
                         + MODE_SEP_PSUMS["chol"])
            first_zolo = 3
        else:
            first_sep = MODE_SEP_PSUMS[first_mode]
            first_zolo = 1
        # + 1 fused fnorm_pair psum for the peeled residual (the two
        # residual-rule norms ride one length-2 all-reduce; see
        # sep_reduce_ops.fnorm_pair), + (1 Gram + 1 fnorm_pair) per
        # while-loop body, + 1 "zolo" combine in the body
        return {
            "sep": est + first_sep + 1 + 2,
            "zolo": first_zolo + 1,
        }
    return None


def _effective_compute_is_narrow(plan) -> bool:
    """True when the plan's factorization dtype is <= f32 — the regime
    where any f64 compute eqn is a leak."""
    import jax.numpy as jnp

    compute = getattr(getattr(plan, "config", None), "compute_dtype", None)
    dtype = jnp.dtype(compute) if compute is not None else jnp.dtype(plan.dtype)
    return jnp.issubdtype(dtype, jnp.floating) and dtype.itemsize <= 4


def audit_plan(plan, *, raise_on_fail: bool = True) -> AuditReport:
    """Audit a live ``SvdPlan`` or ``TopKPlan`` by lowering its traceable
    impl and walking the jaxpr.  Duck-typed: an SvdPlan exposes
    ``_svd_impl`` (richest graph: backend + H + eig stage), a TopKPlan
    ``_impl``."""
    if not hasattr(plan, "_svd_impl") and not hasattr(plan, "_impl"):
        raise TypeError(
            f"audit_plan: {type(plan).__name__} exposes neither _svd_impl "
            f"nor _impl — not a plan object")
    shape = tuple(plan.shape)
    spec = jax.ShapeDtypeStruct(shape, plan.dtype)
    narrow = _effective_compute_is_narrow(plan)

    if hasattr(plan, "_svd_impl"):
        grouped = getattr(plan, "mode", None) == "grouped"
        mesh = getattr(plan, "mesh", None)
        mesh_axes = tuple(mesh.axis_names) if mesh is not None else ()
        expect = None
        if grouped:
            expect = expected_grouped_psums(
                plan.method, plan._backend_kwargs, sep=plan.sep)
        return audit_callable(
            plan._svd_impl, (spec,),
            entry=f"SvdPlan[{plan.method}, {shape}, "
                  f"{jax.numpy.dtype(plan.dtype).name}]",
            mesh_axes=mesh_axes,
            expect_psums=expect,
            allow_collectives=grouped,
            forbid_wide_compute=narrow,
            raise_on_fail=raise_on_fail,
        )
    return audit_callable(
        plan._impl, (spec,),
        entry=f"TopKPlan[{plan.strategy}, {shape}, "
              f"k={plan.config.k}]",
        mesh_axes=(),
        expect_psums=None,
        allow_collectives=False,
        forbid_wide_compute=narrow,
        raise_on_fail=raise_on_fail,
    )


def audit_all_plans(raise_on_fail: bool = False):
    """Audit every plan currently held by the solver and spectral plan
    caches (the pytest fixture's hook: whatever the suite built gets
    walked).  Returns ``[(entry, violations)]`` for the failures."""
    from repro.solver import planner as _planner
    from repro.spectral import topk as _topk

    failures: List[Tuple[str, List[str]]] = []
    plans = (list(_planner._PLANS.values())
             + list(_topk._TOPK_PLANS.values()))
    for plan in plans:
        try:
            report = audit_plan(plan, raise_on_fail=False)
        except Exception as e:  # un-lowerable (e.g. mesh devices gone)
            failures.append((repr(plan), [f"audit could not lower: {e}"]))
            continue
        if not report.ok:
            failures.append((report.entry, report.violations))
    if raise_on_fail and failures:
        raise RuntimeError(f"plan audits failed: {failures}")
    return failures
