"""repro.serve — request-serving engines.

:mod:`repro.serve.svd_service` is the solver-facing subsystem: bucketed
plan pool + continuous micro-batching over ``repro.solver`` plans
(see that module's docstring for the request path and the PR 9 fault-
tolerance layer — verified solves, retry ladders, deadlines, shedding,
circuit breakers).  The typed serving errors (``Backpressure``,
``CircuitOpen``, ``DeadlineExceeded``, ``FutureTimeout``,
``SolveFailure``) live in :mod:`repro.resilience.errors` and are
re-exported here for client convenience.  The LM-shaped ``ServeEngine``
seed scaffolding remains alongside it.
"""

from repro.resilience.errors import (Backpressure, CircuitOpen,
                                     DeadlineExceeded, FutureTimeout,
                                     SolveFailure)
from repro.resilience.faultinject import ServiceFaults
from repro.serve.bucketing import BucketKey, BucketPolicy
from repro.serve.engine import ServeEngine, make_decode_fn, make_prefill_fn
from repro.serve.scheduler import MicroBatchScheduler
from repro.serve.svd_service import (
    DEFAULT_MODES,
    ServiceConfig,
    SvdFuture,
    SvdService,
    topk_mode_k,
)

__all__ = [
    "Backpressure",
    "BucketKey",
    "BucketPolicy",
    "CircuitOpen",
    "DEFAULT_MODES",
    "DeadlineExceeded",
    "FutureTimeout",
    "MicroBatchScheduler",
    "ServeEngine",
    "ServiceConfig",
    "ServiceFaults",
    "SolveFailure",
    "SvdFuture",
    "SvdService",
    "make_decode_fn",
    "make_prefill_fn",
    "topk_mode_k",
]
