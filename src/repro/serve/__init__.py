"""repro.serve — request-serving engines.

:mod:`repro.serve.svd_service` is the solver-facing subsystem: bucketed
plan pool + continuous micro-batching over ``repro.solver`` plans
(see that module's docstring for the request path).  The LM-shaped
``ServeEngine`` seed scaffolding remains alongside it.
"""

from repro.serve.bucketing import BucketKey, BucketPolicy
from repro.serve.engine import ServeEngine, make_decode_fn, make_prefill_fn
from repro.serve.scheduler import MicroBatchScheduler
from repro.serve.svd_service import (
    DEFAULT_MODES,
    ServiceConfig,
    SvdFuture,
    SvdService,
    topk_mode_k,
)

__all__ = [
    "BucketKey",
    "BucketPolicy",
    "DEFAULT_MODES",
    "MicroBatchScheduler",
    "ServeEngine",
    "ServiceConfig",
    "SvdFuture",
    "SvdService",
    "make_decode_fn",
    "make_prefill_fn",
    "topk_mode_k",
]
