"""Continuous micro-batching scheduler for the SVD service.

One FIFO queue per bucket key; :meth:`MicroBatchScheduler.ready` drains
queues into dispatchable batches.  "Continuous" in the LM-serving sense:
slots are refilled *between* dispatches — a batch takes up to
``batch_size`` requests off its queue, the executable runs, and the next
dispatch at that bucket picks up whatever arrived in the meantime.
Nothing waits for a "full epoch" of traffic.

Dispatch policy (anti-starvation by construction):

* A bucket whose queue holds >= ``batch_size`` requests is always
  ready — full batches never wait.
* A partial batch becomes ready once its *head* request has aged past
  ``max_wait``: a rare shape cannot be starved by a hot one, because
  its age — not its queue length — forces the flush.  Empty slots are
  padded by the caller (they keep the compiled batch shape fixed, which
  is what makes the zero-retrace contract hold).
* Ready buckets drain oldest-head-first, so ordering between buckets
  follows arrival order, and requests within one bucket resolve in
  submission order (FIFO pops).

The scheduler is deliberately free of JAX: it moves opaque items
between queues, so its policy is unit-testable with plain objects and
a fake clock.
"""

from __future__ import annotations

import collections
import time
from typing import Any, Dict, Hashable, List, Tuple


class MicroBatchScheduler:
    """Per-bucket FIFO queues drained into fixed-size micro-batches.

    ``batch_size`` is the slot count of every dispatched batch;
    ``max_wait`` (seconds) is the head-of-line age that forces a
    partial dispatch; ``clock`` is injectable for tests (defaults to
    ``time.monotonic``).  :meth:`set_max_wait` overrides the age per
    bucket key — a latency-sensitive lane (small interactive solves)
    can flush early while bulk lanes keep batching for occupancy; keys
    without an override keep the global default, so behavior is
    unchanged unless a caller opts a bucket in.
    """

    def __init__(self, batch_size: int, max_wait: float = 0.005,
                 clock=time.monotonic):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.batch_size = int(batch_size)
        self.max_wait = float(max_wait)
        self._max_wait_by_key: Dict[Hashable, float] = {}
        self._clock = clock
        self._queues: Dict[Hashable, collections.deque] = {}

    def set_max_wait(self, key: Hashable, max_wait: float) -> None:
        """Override the partial-dispatch age for one bucket key
        (idempotent; ``None`` restores the global default)."""
        if max_wait is None:
            self._max_wait_by_key.pop(key, None)
            return
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self._max_wait_by_key[key] = float(max_wait)

    def max_wait_for(self, key: Hashable) -> float:
        """The effective partial-dispatch age of a bucket key."""
        return self._max_wait_by_key.get(key, self.max_wait)

    def enqueue(self, key: Hashable, item: Any, now: float = None) -> None:
        now = self._clock() if now is None else now
        self._queues.setdefault(key, collections.deque()).append((now, item))

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def drop(self, predicate) -> List[Any]:
        """Remove and return every queued item with ``predicate(item)``
        true, preserving FIFO order among the survivors.  The service's
        deadline reaper: expired requests leave the queue *before* they
        can occupy batch slots, and the caller fails their futures with
        a typed error."""
        dropped: List[Any] = []
        for key, q in self._queues.items():
            kept = collections.deque()
            for entry in q:
                if predicate(entry[1]):
                    dropped.append(entry[1])
                else:
                    kept.append(entry)
            self._queues[key] = kept
        return dropped

    def pending_by_key(self) -> Dict[Hashable, int]:
        return {k: len(q) for k, q in self._queues.items() if q}

    def ready(self, now: float = None,
              force: bool = False) -> List[Tuple[Hashable, List[Any]]]:
        """Drain every dispatchable batch: (key, items) pairs, oldest
        head request first.

        Full batches are always taken; partial batches only when the
        head has waited past ``max_wait`` (or ``force=True`` — the
        flush/shutdown path).  A queue longer than one batch yields
        multiple batches in one call, so a burst drains at full slot
        occupancy instead of one batch per poll.
        """
        now = self._clock() if now is None else now
        # queue-creation order breaks timestamp ties: keys need not be
        # orderable (BucketKey and retry-lane keys share one scheduler)
        heads = sorted((q[0][0], i, k) for i, (k, q)
                       in enumerate(self._queues.items()) if q)
        out: List[Tuple[Hashable, List[Any]]] = []
        for t_head, _, key in heads:
            q = self._queues[key]
            while len(q) >= self.batch_size:
                out.append((key, [q.popleft()[1]
                                  for _ in range(self.batch_size)]))
            if q and (force or now - q[0][0] >= self.max_wait_for(key)):
                out.append((key, [q.popleft()[1] for _ in range(len(q))]))
        return out
