"""Batched serving engine: prefill + compiled decode loop.

``serve_step`` (one token for the whole batch against the KV/state cache)
is the unit the decode_* / long_* dry-run shapes lower.  The engine adds:

* greedy / temperature sampling,
* multi-token generation via ``lax.scan`` over the compiled step,
* slot-based continuous batching (finished slots are refilled between
  scan segments; cache capacity is a ring buffer so long sessions do not
  reallocate).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M


def make_prefill_fn(cfg, max_len: int):
    def prefill_fn(params, batch):
        return M.prefill(params, batch, cfg, max_len)

    return prefill_fn


def make_decode_fn(cfg):
    def decode_fn(params, tokens, caches):
        return M.decode_step(params, tokens, caches, cfg)

    return decode_fn


def sample(logits, key, temperature: float = 0.0, vocab_size: int = 0):
    if vocab_size:
        # never sample the padded vocab tail
        neg = jnp.full_like(logits[..., vocab_size:], -1e30)
        logits = jnp.concatenate([logits[..., :vocab_size], neg], axis=-1)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


@dataclasses.dataclass
class ServeEngine:
    cfg: Any
    params: Any
    max_len: int
    temperature: float = 0.0

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_fn(self.cfg, self.max_len))
        self._decode = jax.jit(make_decode_fn(self.cfg))
        cfgv = self.cfg.vocab_size
        temp = self.temperature

        def gen_scan(params, first_tokens, caches, key, steps: int):
            def body(carry, _):
                tokens, caches, key = carry
                key, sub = jax.random.split(key)
                logits, caches = M.decode_step(params, tokens, caches,
                                               self.cfg)
                nxt = sample(logits, sub, temp, cfgv)[:, None]
                return (nxt, caches, key), nxt[:, 0]

            (_, caches, _), toks = jax.lax.scan(
                body, (first_tokens, caches, key), None, length=steps)
            return jnp.moveaxis(toks, 0, 1), caches  # (b, steps)

        self._generate = jax.jit(gen_scan, static_argnames=("steps",))

    def generate(self, batch, steps: int, key=None):
        """batch: {"tokens": (b, s) [, "embeds": ...]} -> (b, steps) int32."""
        key = key if key is not None else jax.random.PRNGKey(0)
        logits, caches = self._prefill(self.params, batch)
        key, sub = jax.random.split(key)
        first = sample(logits, sub, self.temperature,
                       self.cfg.vocab_size)[:, None]
        out, caches = self._generate(self.params, first, caches, key, steps - 1)
        return jnp.concatenate([first, out], axis=1), caches
