"""SVD serving: heterogeneous request stream -> bucketed, micro-batched,
plan-cached solves.

The paper's pitch is throughput — Zolotarev order-r iterations trade
flops for parallelism so many processes finish a factorization sooner —
and the plan/execute surface of PR 2 already compiles one executable per
(shape, dtype, config).  This module turns that cache into a *service*:

    svc = SvdService(ServiceConfig(batch_size=8))
    svc.warmup([(96, 64), (100, 33)])          # populate + pin the pool
    fut = svc.submit(a, mode="standard")       # any (m, n), any dtype
    svc.poll()                                 # drain queues -> dispatch
    u, s, vh = fut.result()                    # blocks HERE, nowhere else

Request path (each stage is its own module):

1.  **Bucketing** (:mod:`repro.serve.bucketing`) — canonical transpose,
    geometric size ladder, zero padding that is exact through the polar
    iteration (f(0) = 0; see that module's proof), spectrum masked back
    out at unpack.
2.  **Scheduling** (:mod:`repro.serve.scheduler`) — continuous
    micro-batching: per-bucket FIFOs drained into fixed-slot batches,
    slots refilled between dispatches, partial batches forced by
    head-of-line age so no shape starves.
3.  **Execution** — ``SvdPlan.svd_batched`` at the bucket's padded
    shape.  The batch slot count is FIXED (empty slots carry zero
    matrices), so each bucket is exactly one compiled executable and
    the steady state performs zero retraces; the plan is re-looked-up
    through ``repro.solver.plan`` on every dispatch, which is what the
    service's plan-cache hit-rate metric measures (warmed buckets are
    ``pin``-ned so LRU pressure from other tenants cannot evict them).
4.  **Response edge** — dispatch is asynchronous (JAX's dispatch
    returns futures-like arrays immediately); completed batches are
    detected with the non-blocking ``Array.is_ready`` sweep, and
    ``jax.block_until_ready`` runs only inside ``SvdFuture.result``.

The service is single-threaded and cooperative: ``submit`` enqueues,
``poll`` dispatches and sweeps, compute overlaps the Python loop via
JAX's async dispatch.  ``result()`` on a not-yet-dispatched future
flushes its bucket, so simple callers never deadlock.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

import repro.solver as _solver
import repro.spectral as _spectral
from repro.analysis import jaxpr_audit as _audit
from repro.serve.bucketing import (
    BucketKey,
    BucketPolicy,
    canonicalize,
    pad_to_bucket,
    pad_waste,
    unpad_svd,
    unpad_topk,
)
from repro.serve.scheduler import MicroBatchScheduler


def topk_mode_k(mode: str) -> Optional[int]:
    """Parse the partial-spectrum lane tag: "topk:<k>" -> k, else None.

    A topk mode is its own bucket dimension — BucketKey.mode carries the
    full tag, so requests at one padded rung but different k compile
    (and batch) separately, which is exactly right: k is a static shape
    parameter of the top-k executable.
    """
    if not str(mode).startswith("topk:"):
        return None
    try:
        k = int(str(mode).split(":", 1)[1])
    except ValueError:
        k = 0
    if k < 1:
        raise ValueError(f"topk mode must be 'topk:<k>' with k >= 1, "
                         f"got {mode!r}")
    return k

# accuracy mode -> plan-time condition-number hint: the knob that sets
# the Zolotarev order r and schedule depth of a bucket's executable.  A
# request whose true kappa exceeds its mode's hint still converges
# monotonically (the composed map is monotone on [0, 1]) but to reduced
# accuracy — that is the contract an accuracy mode buys.
DEFAULT_MODES: Dict[str, float] = {
    "fast": 1e2,
    "standard": 1e4,
    "tight": 1e8,
}


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Frozen serving configuration.

    batch_size   slots per dispatched micro-batch (per bucket); ALSO the
                 compiled batch shape, so it is a plan-pool key knob.
    base/growth  the :class:`BucketPolicy` geometric ladder.
    max_wait     seconds a partial batch's head request may age before
                 the scheduler force-dispatches it with padded slots.
    modes        accuracy-mode tag -> kappa hint (plan-time schedule
                 depth); requests name a tag, never a kappa.
    method       solver method for bucket plans ("auto": the cost model
                 picks per padded shape/dtype).
    max_wait_overrides  per-mode (tag -> seconds) overrides of
                 ``max_wait``: a "topk:<k>" or interactive lane can
                 flush partial batches early while bulk lanes keep
                 batching.  Unlisted modes keep the global default.
    data_axis    optional device list to shard the batch axis over (one
                 matrix per device when batch_size % ndev == 0) — the
                 multi-device serving layout; None keeps single-device
                 dispatch.
    audit_plans  jaxpr-audit every bucket plan at warmup
                 (:func:`repro.analysis.jaxpr_audit.audit_plan`): a plan
                 with a wrong collective structure, an f64 leak, or a
                 host callback fails *before* it serves traffic.
                 ``stats()["plan_audits"]`` reports the counters either
                 way.
    """

    batch_size: int = 4
    base: int = 32
    growth: float = 1.5
    max_wait: float = 0.005
    modes: Tuple[Tuple[str, float], ...] = tuple(
        sorted(DEFAULT_MODES.items()))
    method: str = "auto"
    data_axis: Optional[Tuple[Any, ...]] = None
    max_wait_overrides: Tuple[Tuple[str, float], ...] = ()
    audit_plans: bool = False

    def mode_kappa(self, mode: str) -> float:
        # the partial-spectrum lane rides the "standard" accuracy hint:
        # its k is a shape parameter, not an accuracy tag
        if topk_mode_k(mode) is not None:
            mode = "standard"
        for tag, kappa in self.modes:
            if tag == mode:
                return float(kappa)
        raise ValueError(f"unknown accuracy mode {mode!r} "
                         f"(one of {[t for t, _ in self.modes]})")


@dataclasses.dataclass
class _Request:
    seq: int
    shape: Tuple[int, int]          # original (m, n)
    transposed: bool
    padded: Any                     # canonical, bucket-shaped matrix
    future: "SvdFuture"
    t_submit: float


class SvdFuture:
    """Per-request handle: resolved by the service, blocked only by you.

    States: *queued* (in a bucket FIFO) -> *dispatched* (the batch ran;
    results are async JAX arrays) -> *done* (arrays observed ready by a
    service sweep).  ``result()`` is the response edge — the only place
    ``jax.block_until_ready`` runs; calling it early force-flushes the
    owning bucket so it can never deadlock on an un-filled batch.
    """

    def __init__(self, service: "SvdService", seq: int):
        self._service = service
        self.seq = seq
        self._out = None
        self.t_submit: Optional[float] = None
        self.t_done: Optional[float] = None

    @property
    def dispatched(self) -> bool:
        return self._out is not None

    def done(self) -> bool:
        """Non-blocking: has a sweep observed the results ready?"""
        return self.t_done is not None

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-ready seconds, once done (the benchmark metric)."""
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    def result(self):
        """(u, s, vh) of the request — blocks until ready."""
        while self._out is None:
            self._service.poll(force=True)
        out = jax.block_until_ready(self._out)
        if self.t_done is None:
            self.t_done = self._service._clock()
        return out

    # service-side transitions ------------------------------------------
    def _dispatch(self, out) -> None:
        self._out = out

    def _complete(self, now: float) -> None:
        if self.t_done is None:
            self.t_done = now


@dataclasses.dataclass
class _Inflight:
    key: BucketKey
    raw: Tuple[Any, ...]            # batch-level arrays to probe
    futures: List[SvdFuture]

    def is_ready(self) -> bool:
        return all(a.is_ready() for a in self.raw)


class SvdService:
    """The serving engine: submit -> (bucket, schedule, batch) -> future."""

    def __init__(self, config: ServiceConfig = ServiceConfig(),
                 clock=time.monotonic):
        self.config = config
        self.policy = BucketPolicy(base=config.base, growth=config.growth)
        self._clock = clock
        self._sched = MicroBatchScheduler(config.batch_size,
                                          max_wait=config.max_wait,
                                          clock=clock)
        self._inflight: List[_Inflight] = []
        self._seq = 0
        self._sharding = None
        if config.data_axis is not None:
            ndev = len(config.data_axis)
            if config.batch_size % ndev != 0:
                raise ValueError(
                    f"data_axis has {ndev} devices but batch_size="
                    f"{config.batch_size} does not divide over them")
            mesh = jax.sharding.Mesh(list(config.data_axis), ("data",))
            self._sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data", None, None))
        # serving counters (cache stats are deltas vs these baselines,
        # re-snapshotted by warmup so the steady-state metric is clean)
        self._stats = {"solves": 0, "batches": 0, "slots": 0,
                       "slots_filled": 0, "useful_elems": 0,
                       "padded_elems": 0}
        self._cache_base = _solver.cache_stats()
        self._trace_base = _solver.trace_count()
        self._topk_trace_base = _spectral.trace_count()
        # audit counters are NOT re-baselined by warmup: warmup is where
        # the audits run, and stats() should report them
        self._audit_base = _audit.audit_stats()
        self._wait_overrides = {str(t): float(w)
                                for t, w in config.max_wait_overrides}
        self._warm: List[BucketKey] = []

    # --- plan pool -----------------------------------------------------

    def _bucket_config(self, key: BucketKey) -> _solver.SvdConfig:
        # sub-f32 request dtypes factorize in f32 (there is no stable
        # low-precision Cholesky path) and cast back at the plan edge
        compute = ("float32"
                   if jnp.dtype(key.dtype).itemsize < 4 else None)
        return _solver.SvdConfig(method=self.config.method,
                                 kappa=self.config.mode_kappa(key.mode),
                                 l0_policy="estimate_at_plan",
                                 compute_dtype=compute)

    def _bucket_plan(self, key: BucketKey):
        k = topk_mode_k(key.mode)
        if k is None:
            return _solver.plan(self._bucket_config(key),
                                (key.m_pad, key.n_pad), key.dtype)
        inner = self._bucket_config(key)
        cfg = _spectral.TopKConfig(k=k, kappa=inner.kappa, svd=inner)
        return _spectral.plan_topk(cfg, (key.m_pad, key.n_pad),
                                   key.dtype)

    def warmup(self, shapes: Sequence[Tuple[int, int]],
               modes: Sequence[str] = ("standard",),
               dtypes: Sequence[Any] = ("float64",)) -> List[BucketKey]:
        """Populate and pin the plan pool for an expected workload.

        For every (shape, mode, dtype) combination: resolve the bucket,
        build (or cache-hit) its plan, ``pin`` it against LRU eviction,
        and run one zero-filled batch through ``svd_batched`` so the
        batch executable is compiled *before* traffic arrives.  Returns
        the warmed keys; cache/trace baselines are re-snapshotted, so
        ``stats()`` afterwards reports steady-state hit rate and
        retraces (the zero-retrace contract the tests assert).
        """
        keys: List[BucketKey] = []
        for dtype in dtypes:
            for mode in modes:
                for shape in shapes:
                    key = self.policy.key_for(shape, dtype, mode)
                    if key in keys:
                        continue
                    keys.append(key)
                    plan = self._bucket_plan(key)
                    if self.config.audit_plans:
                        # fail loud at warmup, not under traffic: the
                        # graph invariants (psum structure, dtype
                        # discipline, no callbacks) are checked on the
                        # exact impl the bucket will serve
                        plan.audit()
                    zeros = jnp.zeros(
                        (self.config.batch_size, key.m_pad, key.n_pad),
                        jnp.dtype(key.dtype))
                    if self._sharding is not None:
                        zeros = jax.device_put(zeros, self._sharding)
                    if topk_mode_k(key.mode) is None:
                        _solver.pin(plan)
                        jax.block_until_ready(plan.svd_batched(zeros))
                    else:
                        # a TopKPlan's executables live on the plan; pin
                        # its inner SvdPlans against LRU pressure
                        for inner in plan._inner.values():
                            _solver.pin(inner)
                        jax.block_until_ready(plan.topk_batched(zeros))
        self._warm.extend(keys)
        self._cache_base = _solver.cache_stats()
        self._trace_base = _solver.trace_count()
        self._topk_trace_base = _spectral.trace_count()
        return keys

    # --- request path --------------------------------------------------

    def submit(self, a, mode: str = "standard") -> SvdFuture:
        """Enqueue one (m, n) SVD request; returns its future.

        Accepts any 2-D matrix (tall, wide, square) of any dtype the
        solver takes.  The call is non-blocking: padding is a cheap
        async device op and dispatch happens at the next ``poll``.
        """
        a = jnp.asarray(a)
        if a.ndim != 2:
            raise ValueError(f"SVD requests are one (m, n) matrix; got "
                             f"shape {tuple(a.shape)}")
        self.config.mode_kappa(mode)  # fail fast on unknown tags
        k = topk_mode_k(mode)
        if k is not None and k > min(a.shape):
            raise ValueError(
                f"mode {mode!r} asks for {k} triplets but the request "
                f"is {tuple(a.shape)} (rank at most {min(a.shape)})")
        now = self._clock()
        key = self.policy.key_for(a.shape, a.dtype, mode)
        wait = self._wait_overrides.get(str(mode))
        if wait is not None:
            self._sched.set_max_wait(key, wait)
        a_c, transposed = canonicalize(a)
        fut = SvdFuture(self, self._seq)
        fut.t_submit = now
        req = _Request(seq=self._seq, shape=tuple(a.shape),
                       transposed=transposed,
                       padded=pad_to_bucket(a_c, key.m_pad, key.n_pad),
                       future=fut, t_submit=now)
        self._seq += 1
        self._sched.enqueue(key, req, now=now)
        return fut

    def poll(self, force: bool = False) -> int:
        """Dispatch every ready micro-batch and sweep completions.

        Non-blocking; returns the number of batches dispatched.
        ``force=True`` flushes partial batches regardless of age (the
        shutdown / explicit-flush path).
        """
        dispatched = 0
        for key, reqs in self._sched.ready(now=self._clock(), force=force):
            self._dispatch(key, reqs)
            dispatched += 1
        self._sweep()
        return dispatched

    def flush(self) -> None:
        """Dispatch everything pending and block until all results are
        ready (the only batch-level block in the service)."""
        while self._sched.pending():
            self.poll(force=True)
        for flight in self._inflight:
            jax.block_until_ready(flight.raw)
        self._sweep()

    def _dispatch(self, key: BucketKey, reqs: List[_Request]) -> None:
        plan = self._bucket_plan(key)  # LRU hit in steady state
        slots = self.config.batch_size
        dtype = jnp.dtype(key.dtype)
        mats = [r.padded for r in reqs]
        if len(mats) < slots:
            # fixed batch shape = one executable per bucket; a zero
            # matrix is solver-exact (every factor is zero) and cheap
            mats += [jnp.zeros((key.m_pad, key.n_pad), dtype)] * \
                (slots - len(mats))
        batch = jnp.stack(mats)
        if self._sharding is not None:
            batch = jax.device_put(batch, self._sharding)
        k = topk_mode_k(key.mode)
        if k is None:
            u_b, s_b, vh_b = plan.svd_batched(batch)
        else:
            u_b, s_b, vh_b = plan.topk_batched(batch)
        futures = []
        for i, r in enumerate(reqs):
            m, n = r.shape
            mc, nc = (n, m) if r.transposed else (m, n)
            if k is None:
                out = unpad_svd(u_b[i], s_b[i], vh_b[i], mc, nc,
                                r.transposed)
            else:
                out = unpad_topk(u_b[i], s_b[i], vh_b[i], mc, nc, k,
                                 r.transposed)
            r.future._dispatch(out)
            futures.append(r.future)
        self._inflight.append(_Inflight(key, (u_b, s_b, vh_b), futures))
        self._stats["solves"] += len(reqs)
        self._stats["batches"] += 1
        self._stats["slots"] += slots
        self._stats["slots_filled"] += len(reqs)
        self._stats["useful_elems"] += sum(m * n for m, n in
                                           (r.shape for r in reqs))
        self._stats["padded_elems"] += slots * key.m_pad * key.n_pad

    def _sweep(self) -> None:
        """Timestamp completions without blocking: pop in-flight batches
        whose arrays report ready (dispatch order = completion order on
        a single stream)."""
        now = self._clock()
        while self._inflight and self._inflight[0].is_ready():
            flight = self._inflight.pop(0)
            for fut in flight.futures:
                fut._complete(now)

    # --- observability -------------------------------------------------

    def pending(self) -> int:
        return self._sched.pending()

    def stats(self) -> Dict[str, Any]:
        """Serving counters + the plan-pool metrics the scheduler reads.

        ``plan_cache_hit_rate`` is hits/(hits+misses) of
        ``repro.solver.cache_stats()`` since the last ``warmup`` — 1.0
        in steady state over a warmed bucket set.  ``retraces`` counts
        backend traces over the same window — 0 is the zero-retrace
        serving contract.  ``pad_waste`` is the fraction of dispatched
        batch elements spent on padding (shape padding + empty slots).
        """
        cache = _solver.cache_stats()
        hits = cache["hits"] - self._cache_base["hits"]
        misses = cache["misses"] - self._cache_base["misses"]
        looked = hits + misses
        padded = self._stats["padded_elems"]
        return {
            **self._stats,
            "pad_waste": (1.0 - self._stats["useful_elems"] / padded
                          if padded else 0.0),
            "slot_fill": (self._stats["slots_filled"] / self._stats["slots"]
                          if self._stats["slots"] else 1.0),
            "plan_cache_hit_rate": hits / looked if looked else 1.0,
            "plan_cache": cache,
            "retraces": (_solver.trace_count() - self._trace_base
                         + _spectral.trace_count()
                         - self._topk_trace_base),
            "plan_audits": {
                k: _audit.audit_stats()[k] - self._audit_base[k]
                for k in ("audited", "passed", "failed")},
            "warm_buckets": list(self._warm),
            "inflight": len(self._inflight),
            "pending": self._sched.pending(),
        }


def batch_pad_waste(shapes, key: BucketKey, slots: int) -> float:
    """Convenience re-export of :func:`repro.serve.bucketing.pad_waste`
    keyed by a :class:`BucketKey` (benchmark/report helper)."""
    return pad_waste(shapes, key.m_pad, key.n_pad, slots)
