"""SVD serving: heterogeneous request stream -> bucketed, micro-batched,
plan-cached solves.

The paper's pitch is throughput — Zolotarev order-r iterations trade
flops for parallelism so many processes finish a factorization sooner —
and the plan/execute surface of PR 2 already compiles one executable per
(shape, dtype, config).  This module turns that cache into a *service*:

    svc = SvdService(ServiceConfig(batch_size=8))
    svc.warmup([(96, 64), (100, 33)])          # populate + pin the pool
    fut = svc.submit(a, mode="standard")       # any (m, n), any dtype
    svc.poll()                                 # drain queues -> dispatch
    u, s, vh = fut.result()                    # blocks HERE, nowhere else

Request path (each stage is its own module):

1.  **Bucketing** (:mod:`repro.serve.bucketing`) — canonical transpose,
    geometric size ladder, zero padding that is exact through the polar
    iteration (f(0) = 0; see that module's proof), spectrum masked back
    out at unpack.
2.  **Scheduling** (:mod:`repro.serve.scheduler`) — continuous
    micro-batching: per-bucket FIFOs drained into fixed-slot batches,
    slots refilled between dispatches, partial batches forced by
    head-of-line age so no shape starves.
3.  **Execution** — ``SvdPlan.svd_batched`` at the bucket's padded
    shape.  The batch slot count is FIXED (empty slots carry zero
    matrices), so each bucket is exactly one compiled executable and
    the steady state performs zero retraces; the plan is re-looked-up
    through ``repro.solver.plan`` on every dispatch, which is what the
    service's plan-cache hit-rate metric measures (warmed buckets are
    ``pin``-ned so LRU pressure from other tenants cannot evict them).
4.  **Response edge** — dispatch is asynchronous (JAX's dispatch
    returns futures-like arrays immediately); completed batches are
    detected with the non-blocking ``Array.is_ready`` sweep, and
    ``jax.block_until_ready`` runs only inside ``SvdFuture.result``.

The service is single-threaded and cooperative: ``submit`` enqueues,
``poll`` dispatches and sweeps, compute overlaps the Python loop via
JAX's async dispatch.  ``result()`` on a not-yet-dispatched future
flushes its bucket, so simple callers never deadlock.

Fault tolerance (PR 9; see ``src/repro/resilience/README.md`` for the
failure-mode map): with ``ServiceConfig.verify`` (the default) every
dispatched batch runs ``svd_batched_verified`` — the in-graph
:class:`repro.resilience.health.SolveHealth` rides back with the
factors — and the completion sweep *triages* each ready batch
per-entry: healthy entries resolve, unhealthy ones retry on the next
rung of the bucket's escalation ladder (clean input, fresh plan through
the LRU cache), and entries out of retries are quarantined with a typed
:class:`~repro.resilience.errors.SolveFailure` carrying their verdict
trail.  Around that core: per-request deadlines
(:class:`DeadlineExceeded`), submit-time load shedding
(:class:`Backpressure`), a per-bucket circuit breaker
(:class:`CircuitOpen`), and dispatch-exception propagation into every
affected future — so every future terminates in a result or a typed
error, never a hang.  ``ServiceConfig.faults`` injects deterministic
faults (:class:`repro.resilience.faultinject.ServiceFaults`) for chaos
testing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

import repro.solver as _solver
import repro.spectral as _spectral
from repro.analysis import jaxpr_audit as _audit
from repro.resilience import escalate as _escalate
from repro.resilience import health as _health
from repro.resilience.errors import (Backpressure, CircuitOpen,
                                     DeadlineExceeded, FutureTimeout,
                                     SolveFailure)
from repro.resilience.faultinject import ServiceFaults
from repro.serve.bucketing import (
    BucketKey,
    BucketPolicy,
    canonicalize,
    pad_to_bucket,
    pad_waste,
    unpad_svd_entry,
    unpad_topk_entry,
)
from repro.serve.scheduler import MicroBatchScheduler


def topk_mode_k(mode: str) -> Optional[int]:
    """Parse the partial-spectrum lane tag: "topk:<k>" -> k, else None.

    A topk mode is its own bucket dimension — BucketKey.mode carries the
    full tag, so requests at one padded rung but different k compile
    (and batch) separately, which is exactly right: k is a static shape
    parameter of the top-k executable.
    """
    if not str(mode).startswith("topk:"):
        return None
    try:
        k = int(str(mode).split(":", 1)[1])
    except ValueError:
        k = 0
    if k < 1:
        raise ValueError(f"topk mode must be 'topk:<k>' with k >= 1, "
                         f"got {mode!r}")
    return k

# accuracy mode -> plan-time condition-number hint: the knob that sets
# the Zolotarev order r and schedule depth of a bucket's executable.  A
# request whose true kappa exceeds its mode's hint still converges
# monotonically (the composed map is monotone on [0, 1]) but to reduced
# accuracy — that is the contract an accuracy mode buys.
DEFAULT_MODES: Dict[str, float] = {
    "fast": 1e2,
    "standard": 1e4,
    "tight": 1e8,
}


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Frozen serving configuration.

    batch_size   slots per dispatched micro-batch (per bucket); ALSO the
                 compiled batch shape, so it is a plan-pool key knob.
    base/growth  the :class:`BucketPolicy` geometric ladder.
    max_wait     seconds a partial batch's head request may age before
                 the scheduler force-dispatches it with padded slots.
    modes        accuracy-mode tag -> kappa hint (plan-time schedule
                 depth); requests name a tag, never a kappa.
    method       solver method for bucket plans ("auto": the cost model
                 picks per padded shape/dtype).
    max_wait_overrides  per-mode (tag -> seconds) overrides of
                 ``max_wait``: a "topk:<k>" or interactive lane can
                 flush partial batches early while bulk lanes keep
                 batching.  Unlisted modes keep the global default.
    data_axis    optional device list to shard the batch axis over (one
                 matrix per device when batch_size % ndev == 0) — the
                 multi-device serving layout; None keeps single-device
                 dispatch.
    audit_plans  jaxpr-audit every bucket plan at warmup
                 (:func:`repro.analysis.jaxpr_audit.audit_plan`): a plan
                 with a wrong collective structure, an f64 leak, or a
                 host callback fails *before* it serves traffic.
                 ``stats()["plan_audits"]`` reports the counters either
                 way.
    verify       run every full-SVD batch through
                 ``svd_batched_verified`` and triage entries by their
                 in-graph health verdict (retry up the escalation
                 ladder, quarantine after ``max_retries``).  Off, the
                 service trusts every solve — the pre-PR-9 behavior.
                 The topk lane is never verified (its sketch path has
                 its own residual check; see ``topk_adaptive``).
    deadline     default per-request deadline in seconds from submit
                 (None: no deadline).  A request still queued — or
                 awaiting a retry — past its deadline fails with
                 ``DeadlineExceeded``; ``submit(deadline=)`` overrides
                 per request.
    max_retries  health-failure retries per request before quarantine
                 (each retry climbs one escalation-ladder rung).
    max_queue_depth  submit-time load shed: a submit that would push
                 the queued-request count past this raises
                 ``Backpressure`` (None: never shed).
    breaker_threshold / breaker_cooldown  per-bucket circuit breaker:
                 after ``breaker_threshold`` consecutive dispatch/plan
                 failures in a bucket, submits to it raise
                 ``CircuitOpen`` for ``breaker_cooldown`` seconds, then
                 the breaker closes and counts afresh.
    faults       deterministic fault-injection plan
                 (:class:`repro.resilience.faultinject.ServiceFaults`)
                 for chaos tests; None in production.
    """

    batch_size: int = 4
    base: int = 32
    growth: float = 1.5
    max_wait: float = 0.005
    modes: Tuple[Tuple[str, float], ...] = tuple(
        sorted(DEFAULT_MODES.items()))
    method: str = "auto"
    data_axis: Optional[Tuple[Any, ...]] = None
    max_wait_overrides: Tuple[Tuple[str, float], ...] = ()
    audit_plans: bool = False
    verify: bool = True
    deadline: Optional[float] = None
    max_retries: int = 2
    max_queue_depth: Optional[int] = None
    breaker_threshold: int = 3
    breaker_cooldown: float = 1.0
    faults: Optional[ServiceFaults] = None

    def mode_kappa(self, mode: str) -> float:
        # the partial-spectrum lane rides the "standard" accuracy hint:
        # its k is a shape parameter, not an accuracy tag
        if topk_mode_k(mode) is not None:
            mode = "standard"
        for tag, kappa in self.modes:
            if tag == mode:
                return float(kappa)
        raise ValueError(f"unknown accuracy mode {mode!r} "
                         f"(one of {[t for t, _ in self.modes]})")


@dataclasses.dataclass
class _Request:
    seq: int
    shape: Tuple[int, int]          # original (m, n)
    transposed: bool
    padded: Any                     # canonical, bucket-shaped matrix
    future: "SvdFuture"
    t_submit: float
    deadline: Optional[float] = None  # absolute service-clock time
    rung: int = 0                     # escalation-ladder rung to run at
    retries: int = 0                  # health-failure retries consumed
    trail: List[Any] = dataclasses.field(default_factory=list)


@dataclasses.dataclass(frozen=True)
class _RetryLane:
    """Scheduler key of a bucket's rung-k retry queue (k >= 1).

    Retries batch among themselves — their plan differs from rung 0's,
    so sharing the primary queue would split compiled batches — while
    the primary ``BucketKey`` lanes and every existing scheduler policy
    stay byte-for-byte unchanged."""

    bucket: BucketKey
    rung: int


class SvdFuture:
    """Per-request handle: resolved by the service, blocked only by you.

    States: *queued* (in a bucket FIFO) -> *dispatched* (the batch ran;
    results are async JAX arrays) -> *resolved* (the sweep verified the
    entry healthy — or, with verification off, at dispatch) or *failed*
    (a typed :class:`repro.resilience.errors.ResilienceError`, or the
    captured dispatch exception).  ``result()`` is the response edge —
    the only place ``jax.block_until_ready`` runs; calling it early
    force-flushes the owning bucket so it can never deadlock on an
    un-filled batch, and a retried request re-dispatches from inside
    the same loop.  A failed future raises its exception from
    ``result()`` — every future terminates, none hang.
    """

    def __init__(self, service: "SvdService", seq: int):
        self._service = service
        self.seq = seq
        self._out = None
        self._exc: Optional[BaseException] = None
        self._resolved = False
        self._flight: Optional["_Inflight"] = None
        self.t_submit: Optional[float] = None
        self.t_done: Optional[float] = None

    @property
    def dispatched(self) -> bool:
        return self._out is not None

    def done(self) -> bool:
        """Non-blocking: resolved or failed?"""
        return self._resolved or self._exc is not None

    def exception(self) -> Optional[BaseException]:
        """The failure, if this future failed (None while live/ok)."""
        return self._exc

    @property
    def latency(self) -> Optional[float]:
        """Submit-to-ready seconds, once done (the benchmark metric)."""
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    def result(self, timeout: Optional[float] = None):
        """(u, s, vh) of the request — blocks until resolved.

        Raises the request's typed error if it failed
        (``SolveFailure`` / ``DeadlineExceeded`` / a captured dispatch
        exception), or :class:`FutureTimeout` after ``timeout`` seconds
        — the request itself stays live and ``result()`` can be called
        again.
        """
        give_up = (None if timeout is None
                   else self._service._now() + float(timeout))
        while not self.done():
            if self._flight is not None:
                # dispatched: wait for the device, then let the sweep
                # triage (resolve / retry / quarantine) this flight
                jax.block_until_ready(self._flight.raw)
            self._service.poll(force=True)
            if give_up is not None and not self.done() \
                    and self._service._now() >= give_up:
                raise FutureTimeout(
                    f"request {self.seq} not resolved within "
                    f"{timeout}s (still "
                    f"{'in flight' if self._flight else 'queued'})")
        if self._exc is not None:
            raise self._exc
        out = jax.block_until_ready(self._out)
        if self.t_done is None:
            self.t_done = self._service._now()
        return out

    # service-side transitions ------------------------------------------
    def _dispatch(self, out, flight: Optional["_Inflight"] = None) -> None:
        self._out = out
        self._flight = flight

    def _resolve(self, now: float) -> None:
        self._resolved = True
        self._flight = None
        if self.t_done is None:
            self.t_done = now

    def _retry(self) -> None:
        # back to *queued*: the unhealthy result must not be returned
        self._out = None
        self._flight = None

    def _fail(self, exc: BaseException, now: float) -> None:
        self._exc = exc
        self._out = None
        self._flight = None
        if self.t_done is None:
            self.t_done = now

    def _complete(self, now: float) -> None:
        self._resolve(now)


@dataclasses.dataclass
class _Inflight:
    key: BucketKey
    raw: Tuple[Any, ...]            # batch-level arrays to probe
    reqs: List[_Request]
    health: Any = None              # batched SolveHealth when verifying
    plan: Any = None                # the plan that ran (for judging)
    reason: str = "as planned"      # ladder rung that actually planned

    @property
    def futures(self) -> List[SvdFuture]:
        return [r.future for r in self.reqs]

    def is_ready(self) -> bool:
        return all(a.is_ready() for a in self.raw)


@dataclasses.dataclass
class _Breaker:
    """Per-bucket failure counter with a cooldown latch."""

    failures: int = 0
    open_until: Optional[float] = None


class SvdService:
    """The serving engine: submit -> (bucket, schedule, batch) -> future."""

    def __init__(self, config: ServiceConfig = ServiceConfig(),
                 clock=time.monotonic):
        self.config = config
        self.policy = BucketPolicy(base=config.base, growth=config.growth)
        self._clock = clock
        self._skew = (config.faults.clock_skew
                      if config.faults is not None else 0.0)
        self._sched = MicroBatchScheduler(config.batch_size,
                                          max_wait=config.max_wait,
                                          clock=self._now)
        self._inflight: List[_Inflight] = []
        self._seq = 0
        self._sharding = None
        if config.data_axis is not None:
            ndev = len(config.data_axis)
            if config.batch_size % ndev != 0:
                raise ValueError(
                    f"data_axis has {ndev} devices but batch_size="
                    f"{config.batch_size} does not divide over them")
            mesh = jax.sharding.Mesh(list(config.data_axis), ("data",))
            self._sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data", None, None))
        # serving counters (cache stats are deltas vs these baselines,
        # re-snapshotted by warmup so the steady-state metric is clean)
        self._stats = {"solves": 0, "batches": 0, "slots": 0,
                       "slots_filled": 0, "useful_elems": 0,
                       "padded_elems": 0, "health_failures": 0,
                       "retries": 0, "quarantined": 0, "shed": 0,
                       "deadline_expired": 0, "dispatch_errors": 0,
                       "circuit_opens": 0, "circuit_rejects": 0}
        self._breakers: Dict[BucketKey, _Breaker] = {}
        self._ladders: Dict[BucketKey, List[Tuple[Any, str]]] = {}
        self._dispatch_count = 0
        self._cache_base = _solver.cache_stats()
        self._trace_base = _solver.trace_count()
        self._topk_trace_base = _spectral.trace_count()
        # audit counters are NOT re-baselined by warmup: warmup is where
        # the audits run, and stats() should report them
        self._audit_base = _audit.audit_stats()
        self._wait_overrides = {str(t): float(w)
                                for t, w in config.max_wait_overrides}
        self._warm: List[BucketKey] = []

    def _now(self) -> float:
        """Service time: the injected clock plus any injected skew —
        every deadline, age, and timestamp reads through here."""
        return self._clock() + self._skew

    # --- plan pool -----------------------------------------------------

    def _bucket_config(self, key: BucketKey) -> _solver.SvdConfig:
        # sub-f32 request dtypes factorize in f32 (there is no stable
        # low-precision Cholesky path) and cast back at the plan edge
        compute = ("float32"
                   if jnp.dtype(key.dtype).itemsize < 4 else None)
        return _solver.SvdConfig(method=self.config.method,
                                 kappa=self.config.mode_kappa(key.mode),
                                 l0_policy="estimate_at_plan",
                                 compute_dtype=compute)

    def _bucket_plan(self, key: BucketKey, rung: int = 0):
        """Plan (or LRU-hit) the bucket's executable for an escalation
        rung; returns ``(plan, reason)`` where ``reason`` names the
        ladder rung that actually planned — rungs are skipped when
        their config cannot plan for this bucket, so the requested
        index alone would mislabel failure trails."""
        k = topk_mode_k(key.mode)
        if k is not None:
            inner = self._bucket_config(key)
            cfg = _spectral.TopKConfig(k=k, kappa=inner.kappa, svd=inner)
            return (_spectral.plan_topk(cfg, (key.m_pad, key.n_pad),
                                        key.dtype), "as planned")
        if rung == 0:
            return (_solver.plan(self._bucket_config(key),
                                 (key.m_pad, key.n_pad), key.dtype),
                    "as planned")
        # retry rung: the bucket's escalation ladder, planned through
        # the same LRU cache.  A rung whose config cannot plan here is
        # skipped upward; past the last rung the ladder's final (most
        # conservative) rung serves every further retry.
        ladder = self._ladder(key)
        err = None
        for cfg, reason in ladder[min(rung, len(ladder) - 1):]:
            try:
                return (_solver.plan(cfg, (key.m_pad, key.n_pad),
                                     key.dtype), reason)
            except (ValueError, TypeError) as e:
                err = e
        raise ValueError(f"no escalation rung of bucket {key} plans: "
                         f"{err}")

    def _ladder(self, key: BucketKey):
        ladder = self._ladders.get(key)
        if ladder is None:
            plan0 = _solver.plan(self._bucket_config(key),
                                 (key.m_pad, key.n_pad), key.dtype)
            ladder = _escalate.escalation_ladder(plan0)
            self._ladders[key] = ladder
        return ladder

    def warmup(self, shapes: Sequence[Tuple[int, int]],
               modes: Sequence[str] = ("standard",),
               dtypes: Sequence[Any] = ("float64",)) -> List[BucketKey]:
        """Populate and pin the plan pool for an expected workload.

        For every (shape, mode, dtype) combination: resolve the bucket,
        build (or cache-hit) its plan, ``pin`` it against LRU eviction,
        and run one zero-filled batch through ``svd_batched`` so the
        batch executable is compiled *before* traffic arrives.  Returns
        the warmed keys; cache/trace baselines are re-snapshotted, so
        ``stats()`` afterwards reports steady-state hit rate and
        retraces (the zero-retrace contract the tests assert).
        """
        keys: List[BucketKey] = []
        for dtype in dtypes:
            for mode in modes:
                for shape in shapes:
                    key = self.policy.key_for(shape, dtype, mode)
                    if key in keys:
                        continue
                    keys.append(key)
                    plan, _ = self._bucket_plan(key)
                    if self.config.audit_plans:
                        # fail loud at warmup, not under traffic: the
                        # graph invariants (psum structure, dtype
                        # discipline, no callbacks) are checked on the
                        # exact impl the bucket will serve
                        plan.audit()
                    zeros = jnp.zeros(
                        (self.config.batch_size, key.m_pad, key.n_pad),
                        jnp.dtype(key.dtype))
                    if self._sharding is not None:
                        zeros = jax.device_put(zeros, self._sharding)
                    if topk_mode_k(key.mode) is None:
                        _solver.pin(plan)
                        # compile the exact executable dispatch will run
                        # (verified solves carry the health reduction)
                        if self.config.verify:
                            jax.block_until_ready(
                                plan.svd_batched_verified(zeros))
                        else:
                            jax.block_until_ready(plan.svd_batched(zeros))
                    else:
                        # a TopKPlan's executables live on the plan; pin
                        # its inner SvdPlans against LRU pressure
                        for inner in plan._inner.values():
                            _solver.pin(inner)
                        jax.block_until_ready(plan.topk_batched(zeros))
        self._warm.extend(keys)
        self._cache_base = _solver.cache_stats()
        self._trace_base = _solver.trace_count()
        self._topk_trace_base = _spectral.trace_count()
        return keys

    # --- request path --------------------------------------------------

    def submit(self, a, mode: str = "standard",
               deadline: Optional[float] = None) -> SvdFuture:
        """Enqueue one (m, n) SVD request; returns its future.

        Accepts any 2-D matrix (tall, wide, square) of any dtype the
        solver takes.  The call is non-blocking: padding is a cheap
        async device op and dispatch happens at the next ``poll``.

        ``deadline`` (seconds from now; default ``config.deadline``)
        bounds how long the request may wait — in the queue or between
        retries — before it fails with ``DeadlineExceeded``.  Raises
        :class:`Backpressure` when the queue is at
        ``config.max_queue_depth`` and :class:`CircuitOpen` while the
        request's bucket breaker is cooling down: both *before*
        enqueueing, so a shed request costs the client one exception
        and the service nothing.
        """
        a = jnp.asarray(a)
        if a.ndim != 2:
            raise ValueError(f"SVD requests are one (m, n) matrix; got "
                             f"shape {tuple(a.shape)}")
        self.config.mode_kappa(mode)  # fail fast on unknown tags
        k = topk_mode_k(mode)
        if k is not None and k > min(a.shape):
            raise ValueError(
                f"mode {mode!r} asks for {k} triplets but the request "
                f"is {tuple(a.shape)} (rank at most {min(a.shape)})")
        now = self._now()
        depth = self.config.max_queue_depth
        if depth is not None and self._sched.pending() >= depth:
            self._stats["shed"] += 1
            raise Backpressure(
                f"queue depth {self._sched.pending()} at its limit "
                f"{depth}; back off and resubmit")
        key = self.policy.key_for(a.shape, a.dtype, mode)
        self._check_breaker(key, now)
        wait = self._wait_overrides.get(str(mode))
        if wait is not None:
            self._sched.set_max_wait(key, wait)
        a_c, transposed = canonicalize(a)
        fut = SvdFuture(self, self._seq)
        fut.t_submit = now
        if deadline is None:
            deadline = self.config.deadline
        req = _Request(seq=self._seq, shape=tuple(a.shape),
                       transposed=transposed,
                       padded=pad_to_bucket(a_c, key.m_pad, key.n_pad),
                       future=fut, t_submit=now,
                       deadline=(None if deadline is None
                                 else now + float(deadline)))
        self._seq += 1
        self._sched.enqueue(key, req, now=now)
        return fut

    # --- circuit breaker ----------------------------------------------

    def _check_breaker(self, key: BucketKey, now: float) -> None:
        br = self._breakers.get(key)
        if br is None or br.open_until is None:
            return
        if now < br.open_until:
            self._stats["circuit_rejects"] += 1
            raise CircuitOpen(
                f"bucket {key} breaker open for another "
                f"{br.open_until - now:.3g}s after {br.failures} "
                f"consecutive failures")
        # cooldown over: close and count afresh
        self._breakers[key] = _Breaker()

    def _breaker_failure(self, key: BucketKey, now: float) -> None:
        br = self._breakers.setdefault(key, _Breaker())
        br.failures += 1
        if br.failures >= self.config.breaker_threshold \
                and br.open_until is None:
            br.open_until = now + self.config.breaker_cooldown
            self._stats["circuit_opens"] += 1

    def _breaker_success(self, key: BucketKey) -> None:
        br = self._breakers.get(key)
        if br is not None and br.open_until is None:
            br.failures = 0

    def poll(self, force: bool = False) -> int:
        """Reap deadlines, dispatch ready micro-batches, sweep and
        triage completions.

        Non-blocking; returns the number of batches dispatched.
        ``force=True`` flushes partial batches regardless of age (the
        shutdown / explicit-flush path).
        """
        now = self._now()
        expired = self._sched.drop(
            lambda r: r.deadline is not None and now >= r.deadline)
        for r in expired:
            self._stats["deadline_expired"] += 1
            r.future._fail(DeadlineExceeded(
                f"request {r.seq} expired after "
                f"{now - r.t_submit:.3g}s in queue"), now)
        dispatched = 0
        for key, reqs in self._sched.ready(now=now, force=force):
            self._dispatch(key, reqs)
            dispatched += 1
        self._sweep()
        return dispatched

    def flush(self) -> None:
        """Dispatch everything pending — retries included — and block
        until every future is terminal (the only batch-level block in
        the service)."""
        while self._sched.pending() or self._inflight:
            self.poll(force=True)
            for flight in self._inflight:
                jax.block_until_ready(flight.raw)
            self._sweep()

    def _dispatch(self, lane, reqs: List[_Request]) -> None:
        if isinstance(lane, _RetryLane):
            key, rung = lane.bucket, lane.rung
        else:
            key, rung = lane, 0
        now = self._now()
        idx = self._dispatch_count
        self._dispatch_count += 1
        faults = self.config.faults
        k = topk_mode_k(key.mode)
        try:
            if faults is not None and idx in faults.dispatch_error_batches:
                raise RuntimeError(faults.dispatch_error)
            plan, reason = self._bucket_plan(key, rung)  # LRU hit in steady state
            slots = self.config.batch_size
            dtype = jnp.dtype(key.dtype)
            mats = [r.padded for r in reqs]
            if faults is not None and faults.nan_request_seqs:
                for i, r in enumerate(reqs):
                    if r.seq in faults.nan_request_seqs \
                            and r.rung < faults.nan_below_rung:
                        # corrupt the dispatched copy only: the request
                        # keeps its clean input for retries
                        mats[i] = jnp.full_like(r.padded, float("nan"))
            if len(mats) < slots:
                # fixed batch shape = one executable per bucket; a zero
                # matrix is solver-exact (every factor is zero) and cheap
                mats += [jnp.zeros((key.m_pad, key.n_pad), dtype)] * \
                    (slots - len(mats))
            batch = jnp.stack(mats)
            if self._sharding is not None:
                batch = jax.device_put(batch, self._sharding)
            health = None
            if k is not None:
                u_b, s_b, vh_b = plan.topk_batched(batch)
                raw = (u_b, s_b, vh_b)
            elif self.config.verify:
                u_b, s_b, vh_b, health = plan.svd_batched_verified(batch)
                # health leaves ride in raw so is_ready covers them
                raw = (u_b, s_b, vh_b) + tuple(health)
            else:
                u_b, s_b, vh_b = plan.svd_batched(batch)
                raw = (u_b, s_b, vh_b)
        except Exception as e:  # noqa: BLE001 — every dispatch failure,
            # whatever its type, must reach the batch's futures: an
            # exception escaping here would leave them pending forever
            self._stats["dispatch_errors"] += 1
            self._breaker_failure(key, now)
            for r in reqs:
                r.future._fail(e, now)
            return
        flight = _Inflight(key, raw, list(reqs), health=health, plan=plan,
                           reason=reason)
        for i, r in enumerate(reqs):
            m, n = r.shape
            mc, nc = (n, m) if r.transposed else (m, n)
            if k is None:
                out = unpad_svd_entry(u_b, s_b, vh_b, i, mc, nc,
                                      r.transposed)
            else:
                out = unpad_topk_entry(u_b, s_b, vh_b, i, mc, nc, k,
                                       r.transposed)
            r.future._dispatch(out, flight)
        self._inflight.append(flight)
        self._stats["solves"] += len(reqs)
        self._stats["batches"] += 1
        self._stats["slots"] += slots
        self._stats["slots_filled"] += len(reqs)
        self._stats["useful_elems"] += sum(m * n for m, n in
                                           (r.shape for r in reqs))
        self._stats["padded_elems"] += slots * key.m_pad * key.n_pad

    def _sweep(self) -> None:
        """Pop ready in-flight batches (dispatch order = completion
        order on a single stream) and triage each entry by its health
        verdict: resolve, retry on the next escalation rung, or
        quarantine.  Unverified flights (topk lane, ``verify=False``)
        resolve wholesale, as before PR 9."""
        now = self._now()
        while self._inflight and self._inflight[0].is_ready():
            flight = self._inflight.pop(0)
            if flight.health is None:
                for r in flight.reqs:
                    r.future._resolve(now)
                self._breaker_success(flight.key)
                continue
            h = jax.device_get(flight.health)
            all_ok = True
            for i, r in enumerate(flight.reqs):
                entry = _health.SolveHealth(
                    finite=h.finite[i], orth=h.orth[i],
                    converged=h.converged[i], kappa_est=h.kappa_est[i])
                verdict = _health.judge_plan(flight.plan, entry)
                if verdict.ok:
                    r.future._resolve(now)
                    continue
                all_ok = False
                self._stats["health_failures"] += 1
                r.trail.append(_escalate.RungAttempt(
                    rung=r.rung, reason=flight.reason,
                    config=flight.plan.config, outcome="failed",
                    verdict=verdict))
                if r.deadline is not None and now >= r.deadline:
                    self._stats["deadline_expired"] += 1
                    r.future._fail(DeadlineExceeded(
                        f"request {r.seq} expired after failing its "
                        f"health check (no time left to retry)"), now)
                elif r.retries >= self.config.max_retries:
                    self._stats["quarantined"] += 1
                    r.future._fail(SolveFailure(tuple(r.trail)), now)
                else:
                    r.retries += 1
                    r.rung += 1
                    self._stats["retries"] += 1
                    r.future._retry()
                    self._sched.enqueue(_RetryLane(flight.key, r.rung),
                                        r, now=now)
            if all_ok:
                self._breaker_success(flight.key)
            else:
                self._breaker_failure(flight.key, now)

    # --- observability -------------------------------------------------

    def pending(self) -> int:
        return self._sched.pending()

    def stats(self) -> Dict[str, Any]:
        """Serving counters + the plan-pool metrics the scheduler reads.

        ``plan_cache_hit_rate`` is hits/(hits+misses) of
        ``repro.solver.cache_stats()`` since the last ``warmup`` — 1.0
        in steady state over a warmed bucket set.  ``retraces`` counts
        backend traces over the same window — 0 is the zero-retrace
        serving contract.  ``pad_waste`` is the fraction of dispatched
        batch elements spent on padding (shape padding + empty slots).
        """
        cache = _solver.cache_stats()
        hits = cache["hits"] - self._cache_base["hits"]
        misses = cache["misses"] - self._cache_base["misses"]
        looked = hits + misses
        padded = self._stats["padded_elems"]
        return {
            **self._stats,
            "pad_waste": (1.0 - self._stats["useful_elems"] / padded
                          if padded else 0.0),
            "slot_fill": (self._stats["slots_filled"] / self._stats["slots"]
                          if self._stats["slots"] else 1.0),
            "plan_cache_hit_rate": hits / looked if looked else 1.0,
            "plan_cache": cache,
            "retraces": (_solver.trace_count() - self._trace_base
                         + _spectral.trace_count()
                         - self._topk_trace_base),
            "plan_audits": {
                k: _audit.audit_stats()[k] - self._audit_base[k]
                for k in ("audited", "passed", "failed")},
            "warm_buckets": list(self._warm),
            "inflight": len(self._inflight),
            "pending": self._sched.pending(),
        }


def batch_pad_waste(shapes, key: BucketKey, slots: int) -> float:
    """Convenience re-export of :func:`repro.serve.bucketing.pad_waste`
    keyed by a :class:`BucketKey` (benchmark/report helper)."""
    return pad_waste(shapes, key.m_pad, key.n_pad, slots)
