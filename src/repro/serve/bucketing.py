"""Shape/dtype bucketing for SVD serving: the padded plan-key ladder.

A request stream carries arbitrary (m, n) problems, but compiled-
executable reuse (the whole point of the PR-2 plan cache) needs a SMALL
set of (shape, dtype, config) keys.  The bridge is a geometric size
ladder: every request is canonically oriented (rows >= cols; wide
inputs transpose in and their factors transpose back out), zero-padded
up to the next rung (M, N), and solved through the ONE plan for that
rung.  The spectrum is then masked back out of the padded factors.

Why zero padding is *exact* here, in two steps:

* **Zero rows** change nothing: the Gram X^T X — the only way the
  iteration touches the row space — is unchanged, so every singular
  value and right vector is identical and the extra left rows stay
  exactly zero.  This is the same padding `repro.dist.grouped` proves
  per-shard when it rounds m up to a multiple of the "sep" axis.
* **Zero columns** inject exactly (N - n) *zero* singular values.  The
  composed Zolotarev (and QDWH) map is an odd rational function with
  f(0) = 0, so the injected values stay exactly 0 through every polar
  iteration (the shifted Gram G + cI remains positive definite — c > 0
  — so no factorization ever fails), the H-stage sees a block-diagonal
  H = diag(H_A, 0), and the descending sort parks the injected zeros at
  the tail of the spectrum.  :func:`unpad_svd` slices them off.

The measured cost of padding is the pad-waste fraction
(:func:`pad_waste`): the fraction of batched flops spent on zeros.  The
ladder's ``growth`` trades that waste against the number of live
compiled executables — the serving analog of a paging granularity knob.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class BucketKey(NamedTuple):
    """One padded plan key: everything that selects a compiled executable.

    ``m_pad >= n_pad`` always (canonical orientation); ``dtype`` is the
    request dtype's canonical string name; ``mode`` is the service
    accuracy-mode tag (it selects the plan's kappa hint / schedule
    depth, so two modes at one padded shape are two executables).
    """

    m_pad: int
    n_pad: int
    dtype: str
    mode: str


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Geometric size ladder: rungs are ``base * ceil(growth^k)``.

    ``base`` floors the smallest rung (tiny problems share one bucket
    instead of one executable each); ``growth`` bounds per-dimension
    overpadding at ``growth``x, i.e. the worst-case pad-waste fraction
    of a single request at ``1 - 1/growth^2`` — the default 1.5 ladder
    (32, 48, 72, 108, 162, 243, ...) caps it at ~55% while keeping the
    rung count logarithmic in the served shape range.
    """

    base: int = 32
    growth: float = 1.5

    def __post_init__(self):
        if self.base < 1:
            raise ValueError(f"bucket base must be >= 1, got {self.base}")
        if self.growth <= 1.0:
            raise ValueError(
                f"bucket growth must be > 1 (the ladder must climb), "
                f"got {self.growth}")

    def rung(self, size: int) -> int:
        """Smallest ladder rung >= size."""
        if size < 1:
            raise ValueError(f"bucketed dimensions are >= 1, got {size}")
        s = self.base
        while s < size:
            s = int(math.ceil(s * self.growth))
        return s

    def key_for(self, shape: Tuple[int, int], dtype, mode: str) -> "BucketKey":
        """The padded plan key serving a (m, n) request.

        Orientation-free: (m, n) and (n, m) land in the same bucket
        (the service transposes wide inputs to canonical rows >= cols
        before padding).
        """
        m, n = int(shape[0]), int(shape[1])
        if m < n:
            m, n = n, m
        return BucketKey(self.rung(m), self.rung(n),
                         jnp.dtype(dtype).name, str(mode))


def canonicalize(a):
    """(a_canonical, transposed) with rows >= cols.

    Same convention as ``repro.core.zolo.polar_canonical``; the service
    applies it *before* padding so every bucket is tall and
    :func:`unpad_svd` undoes it after masking.
    """
    m, n = a.shape[-2], a.shape[-1]
    if m >= n:
        return a, False
    return jnp.swapaxes(a, -1, -2), True


def pad_to_bucket(a, m_pad: int, n_pad: int):
    """Zero-pad a canonical (m, n) matrix to the (m_pad, n_pad) rung."""
    m, n = a.shape[-2], a.shape[-1]
    if m > m_pad or n > n_pad:
        raise ValueError(f"matrix {a.shape} does not fit bucket "
                         f"({m_pad}, {n_pad})")
    if (m, n) == (m_pad, n_pad):
        return a
    return jnp.pad(a, ((0, m_pad - m), (0, n_pad - n)))


def unpad_svd(u, s, vh, m: int, n: int, transposed: bool):
    """Mask the padded spectrum back out of a bucket-shaped SVD.

    ``u`` (m_pad, n_pad) / ``s`` (n_pad,) / ``vh`` (n_pad, n_pad) are
    the padded solve of a canonical (m, n) request.  The n genuine
    singular triplets must be *identified by padded index, not by
    value*: the injected triplets' values are exactly 0 (see the module
    docstring), but a rank-deficient request has genuine zeros too, and
    the descending sort breaks those ties arbitrarily — slicing the
    first n entries could then keep an injected triplet (a padded-
    column basis vector, zero everywhere the request lives) and drop a
    genuine null-space vector.  The discriminator is right-vector mass
    on the request's own columns: genuine vectors carry all of it,
    injected ones exactly none, so a stable partition by that mask
    selects the n genuine triplets while preserving the descending
    value order.  For a transposed (originally wide) request the
    factors swap back: A = (U S Vh)^T = V S U^T.
    """
    n_pad = s.shape[-1]
    if n_pad != n:
        mass = jnp.sum(vh[..., :n] ** 2, axis=-1)
        # 0 = genuine (mass ~ 1), 1 = injected (mass exactly 0); stable
        # argsort keeps the descending-s order within each class
        idx = jnp.argsort((mass < 0.5).astype(jnp.int32), axis=-1,
                          stable=True)[..., :n]
        s = jnp.take_along_axis(s, idx, axis=-1)
        u = jnp.take_along_axis(u, idx[..., None, :], axis=-1)
        vh = jnp.take_along_axis(vh, idx[..., :, None], axis=-2)
    u = u[..., :m, :n]
    s = s[..., :n]
    vh = vh[..., :n, :n]
    if transposed:
        return jnp.swapaxes(vh, -1, -2), s, jnp.swapaxes(u, -1, -2)
    return u, s, vh


def unpad_topk(u, s, vh, m: int, n: int, k: int, transposed: bool):
    """Mask padding out of a bucket-shaped *top-k* solve.

    ``u`` (m_pad, k) / ``s`` (k,) / ``vh`` (k, n_pad) from the padded
    top-k of a canonical (m, n) request.  Padding exactness carries
    over from the full case: zero rows leave the Gram unchanged and
    zero columns inject exactly-zero singular values, which a top-k
    solve with k <= n (validated at submit) never ranks above a genuine
    nonzero triplet.  (When k exceeds the request's *rank*, trailing
    s = 0 triplets may point anywhere in the padded null space — their
    sliced right vectors are then not unit norm, but they carry zero
    weight in any reconstruction.)
    """
    u = u[..., :m, :k]
    s = s[..., :k]
    vh = vh[..., :k, :n]
    if transposed:
        return jnp.swapaxes(vh, -1, -2), s, jnp.swapaxes(u, -1, -2)
    return u, s, vh


@functools.partial(jax.jit, static_argnames=("m", "n", "transposed"))
def unpad_svd_entry(u_b, s_b, vh_b, i, m, n, transposed: bool):
    """One batch entry's :func:`unpad_svd`, fused into a single compiled
    call.

    The eager form costs ~10 op-by-op dispatches per request (the batch
    gathers plus the partition/slice chain) — enough to make the serving
    loop host-bound at small matrix sizes.  One jit per
    (batch shape, request shape, orientation) collapses that to a single
    dispatch; ``i`` is traced, so every slot of a bucket shares the
    compilation.
    """
    return unpad_svd(u_b[i], s_b[i], vh_b[i], m, n, transposed)


@functools.partial(jax.jit, static_argnames=("m", "n", "k", "transposed"))
def unpad_topk_entry(u_b, s_b, vh_b, i, m, n, k: int, transposed: bool):
    """One batch entry's :func:`unpad_topk` as a single compiled call
    (same host-dispatch argument as :func:`unpad_svd_entry`)."""
    return unpad_topk(u_b[i], s_b[i], vh_b[i], m, n, k, transposed)


def pad_waste(shapes, m_pad: int, n_pad: int, slots: int) -> float:
    """Fraction of a dispatched (slots, m_pad, n_pad) batch spent on
    padding: 1 - useful/total, counting empty slots as pure waste."""
    useful = sum(min(m, n) * max(m, n) for m, n in shapes)
    total = slots * m_pad * n_pad
    return 1.0 - useful / total if total else 0.0
