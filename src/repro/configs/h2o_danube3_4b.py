"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention
[arXiv:2401.16818].  Window 4096 (mistral-style SWA) -> sub-quadratic
serving, so this arch runs the long_500k shape."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    mlp_type="swiglu",
    window=4096,
    rope_theta=1e4,
).validate()

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=192, vocab_size=256, window=32, dtype="float32",
).validate()
