"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
— llama-arch GQA [arXiv:2403.04652; hf]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    mlp_type="swiglu",
    rope_theta=5e6,
).validate()

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=192, vocab_size=256, dtype="float32",
).validate()
