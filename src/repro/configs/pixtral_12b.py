"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT + mistral-nemo [hf:mistralai/Pixtral-12B-2409].

Backbone only per the assignment: the Pixtral ViT frontend is a stub —
``input_specs()`` supplies 256 precomputed patch embeddings per sample,
prepended to the text tokens (total sequence = shape.seq_len)."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=14336,
    vocab_size=131072,
    mlp_type="swiglu",
    num_prefix_embeds=256,
    rope_theta=1e9,
).validate()

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=192, vocab_size=256, num_prefix_embeds=8,
    dtype="float32",
).validate()
