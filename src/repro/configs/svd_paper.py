"""The paper's own experimental matrices (Tables 3 and 8), synthesized.

The UF sparse-collection matrices are not downloadable offline, so each is
matched by a synthetic matrix with the same dimension and 2-norm condition
number (geometric singular-value spectrum, Haar-random singular vectors).
Zolo-SVD is a dense direct method (paper §3.2: sparsity is not exploited),
so dimension + conditioning determine both cost and numerical difficulty.
CPU-sized stand-ins (n scaled down, same kappa) drive the wall-clock
benchmarks; full-sized entries drive flop/roofline accounting.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SvdMatrixConfig:
    name: str
    n: int
    cond: float
    cpu_n: int  # reduced size for CPU wall-clock runs
    r_paper: int  # the paper's r choice (Table 3) or 2 (Tables 8/9)


# Table 3 (Example 1) + Table 8 (Example 3).
MATRICES = {
    "nemeth03": SvdMatrixConfig("nemeth03", 9_506, 1.29e0, 768, 2),
    "fv1": SvdMatrixConfig("fv1", 9_604, 1.40e1, 768, 3),
    "linverse": SvdMatrixConfig("linverse", 11_999, 9.06e3, 768, 4),
    "bcsstk18": SvdMatrixConfig("bcsstk18", 11_948, 3.46e11, 768, 2),
    "c-47": SvdMatrixConfig("c-47", 15_343, 3.16e8, 768, 2),
    "c-49": SvdMatrixConfig("c-49", 21_132, 6.02e8, 768, 2),
    "cvxbqp1": SvdMatrixConfig("cvxbqp1", 50_000, 1.09e11, 768, 2),
    "rand1": SvdMatrixConfig("rand1", 10_000, 3.97e7, 768, 2),
    "rand2": SvdMatrixConfig("rand2", 30_000, 1.24e7, 768, 2),
}

# Structured-QR benchmark shapes (paper Table 2).
QR_SHAPES = [(10_000, 5_000), (20_000, 10_000)]
QR_CPU_SHAPES = [(1_536, 768), (3_072, 1_536)]


def synthesize(name: str, *, cpu_size: bool = True, dtype=np.float64,
               seed: int = 0) -> np.ndarray:
    """Dense synthetic stand-in with matched n (or cpu_n) and kappa_2."""
    if name not in MATRICES:
        raise ValueError(f"unknown paper matrix {name!r}; known: "
                         f"{sorted(MATRICES)}")
    cfg = MATRICES[name]
    n = cfg.cpu_n if cpu_size else cfg.n
    rng = np.random.default_rng(seed + hash(name) % (2 ** 16))
    s = np.geomspace(1.0, 1.0 / cfg.cond, n)
    # Haar-random U, V via QR of Gaussian
    u, _ = np.linalg.qr(rng.standard_normal((n, n)))
    v, _ = np.linalg.qr(rng.standard_normal((n, n)))
    return (u * s) @ v.T if dtype == np.float64 else \
        ((u * s) @ v.T).astype(dtype)
