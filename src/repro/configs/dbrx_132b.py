"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) expert d_ff=10752
vocab=100352, MoE 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    mlp_type="swiglu",
    num_experts=16,
    moe_top_k=4,
    capacity_factor=1.25,
    rope_theta=5e5,
).validate()

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=96, vocab_size=256, num_experts=4, moe_top_k=2,
    dtype="float32",
).validate()
