"""musicgen-large [audio]: 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only per the assignment: the EnCodec frontend is a stub — the
token stream *is* the EnCodec codebook stream (single-stream
simplification of the 4-codebook interleave; DESIGN.md §5).  MusicGen's
original sinusoidal positions are replaced by the framework-standard RoPE
(positional-encoding swap noted in DESIGN.md; no effect on shapes/flops).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    rope_theta=1e4,
).validate()

SMOKE = dataclasses.replace(
    CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256, dtype="float32",
).validate()
