"""--arch registry: config lookup + per-(arch x shape) input specs."""

from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

ARCHS = {
    "musicgen-large": "repro.configs.musicgen_large",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "yi-34b": "repro.configs.yi_34b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "olmo-1b": "repro.configs.olmo_1b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}


def list_archs():
    return sorted(ARCHS)


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    return importlib.import_module(ARCHS[arch])


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if (arch x shape) is runnable, else the skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return "SKIP(full-attn): long_500k needs sub-quadratic attention"
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                abstract: bool = True, seed: int = 0) -> Dict[str, object]:
    """Model data inputs for one cell, as ShapeDtypeStructs (dry-run) or
    concrete deterministic arrays (tests / examples).

    train/prefill:  tokens (B, S - P) int32 [+ embeds (B, P, d) bf16]
    decode:         tokens (B, 1) int32 (the cache comes from init_caches)
    """
    b = shape.global_batch
    p = cfg.num_prefix_embeds
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        specs = {"tokens": ((b, 1), jnp.int32)}
    else:
        specs = {"tokens": ((b, shape.seq_len - p), jnp.int32)}
        if p:
            specs["embeds"] = ((b, p, cfg.d_model), dt)
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in specs.items()}
    rng = np.random.default_rng(seed)
    out = {}
    for k, (s, d) in specs.items():
        if d == jnp.int32:
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=s), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(s), d)
    return out
