"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (MHA kv=16) expert
d_ff=1408 vocab=163840, MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B].  Moonlight's shared expert is folded
into the 64-expert pool (noted in DESIGN.md §5)."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    mlp_type="swiglu",
    num_experts=64,
    moe_top_k=6,
    capacity_factor=1.25,
    rope_theta=5e4,
).validate()

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=64, vocab_size=256, num_experts=8, moe_top_k=2,
    dtype="float32",
).validate()
