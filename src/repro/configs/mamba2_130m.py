"""mamba2-130m [ssm]: 24L d_model=768 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060].

vocab 50280 is padded to 50432 (multiple of 256) for the 16-wide model
axis; tied embeddings as in the released checkpoints.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    vocab_size=50280,
    d_ff=0,
    mlp_type="none",
    block_pattern=("ssd",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
).validate()

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=32, vocab_size=256, dtype="float32",
).validate()
