"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, pattern (R, R, A)
[arXiv:2402.19427; hf].  26 layers = 8 x (rglru, rglru, attn) + (rglru,
rglru) remainder.  Local attention window 2048 + O(1) RG-LRU state ->
runs the long_500k shape."""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp_type="swiglu",
    block_pattern=("rglru", "rglru", "attn"),
    rnn_width=2560,
    window=2048,
    rope_theta=1e4,
    logits_softcap=30.0,
).validate()

SMOKE = dataclasses.replace(
    CONFIG, num_layers=8, d_model=64, num_heads=4, num_kv_heads=1,
    head_dim=16, d_ff=192, vocab_size=256, rnn_width=64, window=32,
    dtype="float32",
).validate()
